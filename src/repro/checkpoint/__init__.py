"""Fault-tolerant checkpointing: atomic save, retention, auto-resume,
elastic resharding on restore."""
from .manager import CheckpointManager, restore_resharded

__all__ = ["CheckpointManager", "restore_resharded"]
