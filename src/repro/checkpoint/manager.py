"""Checkpoint manager: atomic, validated, retained, elastically reshardable.

Layout (one directory per step)::

    <dir>/step_000042.tmp/...      # written first
    <dir>/step_000042/             # atomic rename after fsync
        manifest.json              # step, leaf paths, shapes, dtypes, crc
        arr_00000.npy ...          # one file per pytree leaf

Failure semantics:
  * a crash mid-save leaves only a ``.tmp`` dir -> ignored and GC'd,
  * ``latest_step`` validates the manifest and every leaf file before
    declaring a checkpoint restorable; corrupt dirs are skipped (the
    previous step is used),
  * retention keeps the newest ``keep`` checkpoints.

Elastic restore: leaves are loaded host-side and ``jax.device_put`` onto the
*target* shardings — the restore mesh may differ from the save mesh
(elastic scaling), since leaves are saved unsharded (per-host gather; on
multi-host pods each host writes its addressable shards and restore
reassembles — single-process here, documented in DESIGN.md §5).
"""
from __future__ import annotations

import json
import os
import shutil
import zlib
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["CheckpointManager", "restore_resharded"]


def _flatten(tree) -> tuple[list, Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ----------------------------------------------------------- save
    def save(self, step: int, state) -> str:
        name = f"step_{step:09d}"
        tmp = os.path.join(self.dir, name + ".tmp")
        final = os.path.join(self.dir, name)
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves, _ = _flatten(state)
        manifest = {"step": step, "leaves": []}
        for i, leaf in enumerate(leaves):
            arr = np.asarray(jax.device_get(leaf))
            path = f"arr_{i:05d}.npy"
            np.save(os.path.join(tmp, path), arr)
            manifest["leaves"].append({
                "path": path,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "crc": zlib.crc32(arr.tobytes()),
            })
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()
        return final

    # -------------------------------------------------------- restore
    def _steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                try:
                    out.append(int(d[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def _valid(self, step: int) -> bool:
        d = os.path.join(self.dir, f"step_{step:09d}")
        mf = os.path.join(d, "manifest.json")
        if not os.path.exists(mf):
            return False
        try:
            with open(mf) as f:
                manifest = json.load(f)
            for leaf in manifest["leaves"]:
                arr = np.load(os.path.join(d, leaf["path"]), mmap_mode="r")
                if list(arr.shape) != leaf["shape"]:
                    return False
            return True
        except Exception:
            return False

    def latest_step(self) -> Optional[int]:
        for step in reversed(self._steps()):
            if self._valid(step):
                return step
        return None

    def restore(self, state_like, step: Optional[int] = None,
                *, verify_crc: bool = False):
        """Restore into the structure (and shardings) of ``state_like``.
        ``state_like`` may be a pytree of arrays or ShapeDtypeStructs with
        ``.sharding`` — leaves are device_put onto those shardings."""
        step = self.latest_step() if step is None else step
        if step is None:
            return None
        d = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        leaves_like, treedef = _flatten(state_like)
        assert len(leaves_like) == len(manifest["leaves"]), (
            "checkpoint/state structure mismatch")
        out = []
        for like, meta in zip(leaves_like, manifest["leaves"]):
            arr = np.load(os.path.join(d, meta["path"]))
            if verify_crc and zlib.crc32(arr.tobytes()) != meta["crc"]:
                raise IOError(f"crc mismatch in {meta['path']}")
            sharding = getattr(like, "sharding", None)
            if sharding is not None and not isinstance(
                    sharding, jax.sharding.SingleDeviceSharding):
                out.append(jax.device_put(arr, sharding))
            else:
                out.append(jax.numpy.asarray(arr))
        return jax.tree.unflatten(treedef, out), step

    # ------------------------------------------------------ retention
    def _gc(self) -> None:
        steps = self._steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)
        for d in os.listdir(self.dir):
            if d.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)


def restore_resharded(mgr: CheckpointManager, state_sds):
    """Elastic restore: load the latest checkpoint onto (possibly different)
    target shardings — the save-time mesh shape is irrelevant."""
    return mgr.restore(state_sds)
