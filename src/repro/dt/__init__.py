"""Decision-tree dataset substrate (paper §III, Table II).

The container is offline, so only Fisher's Iris is embedded (canonical UCI
values); the remaining seven Table II datasets are *synthetic generators
matched to Table II shapes* (instances/features/classes) with planted
axis-aligned rule structure, so CART trees land in the same LUT-size regime
as the paper's Table V.  See DESIGN.md §7.
"""
from .datasets import DATASETS, DatasetSpec, load, load_split, normalize

__all__ = ["DATASETS", "DatasetSpec", "load", "load_split", "normalize"]
