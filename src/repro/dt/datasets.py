"""Table II datasets (paper §III).

Offline container: UCI/Kaggle are unreachable, so

  * **Iris** is embedded (the canonical 150x4 UCI values, 3 classes).
  * The other seven datasets are **synthetic generators matched to Table II**
    (#instances, #features, #classes) with *planted axis-aligned rule
    structure* + label noise, tuned so CART trees land in the same LUT-size
    regime as the paper's Table V.  Absolute accuracies differ from the paper
    (different data); every *relative* claim (sim == golden, robustness
    trends, energy/latency scaling with S) is data-source independent.

Each dataset ships fit parameters (``max_depth``/``max_leaves``) used by the
benchmarks so LUT shapes are reproducible run-to-run (all generators are
seeded and deterministic).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

__all__ = ["DatasetSpec", "DATASETS", "load", "load_split", "normalize", "IRIS"]


# --------------------------------------------------------------------------
# Embedded Fisher's Iris (canonical UCI values): 50 setosa / 50 versicolor /
# 50 virginica, features = sepal length, sepal width, petal length, petal
# width (cm).
# --------------------------------------------------------------------------
_IRIS_RAW = """
5.1 3.5 1.4 0.2 0;4.9 3.0 1.4 0.2 0;4.7 3.2 1.3 0.2 0;4.6 3.1 1.5 0.2 0
5.0 3.6 1.4 0.2 0;5.4 3.9 1.7 0.4 0;4.6 3.4 1.4 0.3 0;5.0 3.4 1.5 0.2 0
4.4 2.9 1.4 0.2 0;4.9 3.1 1.5 0.1 0;5.4 3.7 1.5 0.2 0;4.8 3.4 1.6 0.2 0
4.8 3.0 1.4 0.1 0;4.3 3.0 1.1 0.1 0;5.8 4.0 1.2 0.2 0;5.7 4.4 1.5 0.4 0
5.4 3.9 1.3 0.4 0;5.1 3.5 1.4 0.3 0;5.7 3.8 1.7 0.3 0;5.1 3.8 1.5 0.3 0
5.4 3.4 1.7 0.2 0;5.1 3.7 1.5 0.4 0;4.6 3.6 1.0 0.2 0;5.1 3.3 1.7 0.5 0
4.8 3.4 1.9 0.2 0;5.0 3.0 1.6 0.2 0;5.0 3.4 1.6 0.4 0;5.2 3.5 1.5 0.2 0
5.2 3.4 1.4 0.2 0;4.7 3.2 1.6 0.2 0;4.8 3.1 1.6 0.2 0;5.4 3.4 1.5 0.4 0
5.2 4.1 1.5 0.1 0;5.5 4.2 1.4 0.2 0;4.9 3.1 1.5 0.2 0;5.0 3.2 1.2 0.2 0
5.5 3.5 1.3 0.2 0;4.9 3.6 1.4 0.1 0;4.4 3.0 1.3 0.2 0;5.1 3.4 1.5 0.2 0
5.0 3.5 1.3 0.3 0;4.5 2.3 1.3 0.3 0;4.4 3.2 1.3 0.2 0;5.0 3.5 1.6 0.6 0
5.1 3.8 1.9 0.4 0;4.8 3.0 1.4 0.3 0;5.1 3.8 1.6 0.2 0;4.6 3.2 1.4 0.2 0
5.3 3.7 1.5 0.2 0;5.0 3.3 1.4 0.2 0;7.0 3.2 4.7 1.4 1;6.4 3.2 4.5 1.5 1
6.9 3.1 4.9 1.5 1;5.5 2.3 4.0 1.3 1;6.5 2.8 4.6 1.5 1;5.7 2.8 4.5 1.3 1
6.3 3.3 4.7 1.6 1;4.9 2.4 3.3 1.0 1;6.6 2.9 4.6 1.3 1;5.2 2.7 3.9 1.4 1
5.0 2.0 3.5 1.0 1;5.9 3.0 4.2 1.5 1;6.0 2.2 4.0 1.0 1;6.1 2.9 4.7 1.4 1
5.6 2.9 3.6 1.3 1;6.7 3.1 4.4 1.4 1;5.6 3.0 4.5 1.5 1;5.8 2.7 4.1 1.0 1
6.2 2.2 4.5 1.5 1;5.6 2.5 3.9 1.1 1;5.9 3.2 4.8 1.8 1;6.1 2.8 4.0 1.3 1
6.3 2.5 4.9 1.5 1;6.1 2.8 4.7 1.2 1;6.4 2.9 4.3 1.3 1;6.6 3.0 4.4 1.4 1
6.8 2.8 4.8 1.4 1;6.7 3.0 5.0 1.7 1;6.0 2.9 4.5 1.5 1;5.7 2.6 3.5 1.0 1
5.5 2.4 3.8 1.1 1;5.5 2.4 3.7 1.0 1;5.8 2.7 3.9 1.2 1;6.0 2.7 5.1 1.6 1
5.4 3.0 4.5 1.5 1;6.0 3.4 4.5 1.6 1;6.7 3.1 4.7 1.5 1;6.3 2.3 4.4 1.3 1
5.6 3.0 4.1 1.3 1;5.5 2.5 4.0 1.3 1;5.5 2.6 4.4 1.2 1;6.1 3.0 4.6 1.4 1
5.8 2.6 4.0 1.2 1;5.0 2.3 3.3 1.0 1;5.6 2.7 4.2 1.3 1;5.7 3.0 4.2 1.2 1
5.7 2.9 4.2 1.3 1;6.2 2.9 4.3 1.3 1;5.1 2.5 3.0 1.1 1;5.7 2.8 4.1 1.3 1
6.3 3.3 6.0 2.5 2;5.8 2.7 5.1 1.9 2;7.1 3.0 5.9 2.1 2;6.3 2.9 5.6 1.8 2
6.5 3.0 5.8 2.2 2;7.6 3.0 6.6 2.1 2;4.9 2.5 4.5 1.7 2;7.3 2.9 6.3 1.8 2
6.7 2.5 5.8 1.8 2;7.2 3.6 6.1 2.5 2;6.5 3.2 5.1 2.0 2;6.4 2.7 5.3 1.9 2
6.8 3.0 5.5 2.1 2;5.7 2.5 5.0 2.0 2;5.8 2.8 5.1 2.4 2;6.4 3.2 5.3 2.3 2
6.5 3.0 5.5 1.8 2;7.7 3.8 6.7 2.2 2;7.7 2.6 6.9 2.3 2;6.0 2.2 5.0 1.5 2
6.9 3.2 5.7 2.3 2;5.6 2.8 4.9 2.0 2;7.7 2.8 6.7 2.0 2;6.3 2.7 4.9 1.8 2
6.7 3.3 5.7 2.1 2;7.2 3.2 6.0 1.8 2;6.2 2.8 4.8 1.8 2;6.1 3.0 4.9 1.8 2
6.4 2.8 5.6 2.1 2;7.2 3.0 5.8 1.6 2;7.4 2.8 6.1 1.9 2;7.9 3.8 6.4 2.0 2
6.4 2.8 5.6 2.2 2;6.3 2.8 5.1 1.5 2;6.1 2.6 5.6 1.4 2;7.7 3.0 6.1 2.3 2
6.3 3.4 5.6 2.4 2;6.4 3.1 5.5 1.8 2;6.0 3.0 4.8 1.8 2;6.9 3.1 5.4 2.1 2
6.7 3.1 5.6 2.4 2;6.9 3.1 5.1 2.3 2;5.8 2.7 5.1 1.9 2;6.8 3.2 5.9 2.3 2
6.7 3.3 5.7 2.5 2;6.7 3.0 5.2 2.3 2;6.3 2.5 5.0 1.9 2;6.5 3.0 5.2 2.0 2
6.2 3.4 5.4 2.3 2;5.9 3.0 5.1 1.8 2
"""


def _iris() -> tuple[np.ndarray, np.ndarray]:
    rows = [r for r in _IRIS_RAW.replace("\n", ";").split(";") if r.strip()]
    arr = np.array([[float(v) for v in r.split()] for r in rows])
    assert arr.shape == (150, 5), arr.shape
    return arr[:, :4], arr[:, 4].astype(np.int64)


IRIS = _iris


# --------------------------------------------------------------------------
# Synthetic generators with planted rule structure
# --------------------------------------------------------------------------
def _planted_tree_labels(
    X: np.ndarray,
    n_classes: int,
    depth: int,
    rng: np.random.Generator,
) -> np.ndarray:
    """Label points with a random planted axis-aligned decision tree.

    The planted tree is built by recursive random splits (feature uniform,
    threshold at a random quantile of the points reaching the node), leaves
    get random classes.  This gives CART a learnable rule structure whose
    recovered tree size scales with ``depth``.
    """
    y = np.zeros(X.shape[0], dtype=np.int64)

    def rec(idx: np.ndarray, d: int) -> None:
        if d == 0 or idx.size < 8:
            y[idx] = rng.integers(0, n_classes)
            return
        f = int(rng.integers(0, X.shape[1]))
        q = float(rng.uniform(0.25, 0.75))
        thr = np.quantile(X[idx, f], q)
        mask = X[idx, f] <= thr
        if mask.all() or not mask.any():
            y[idx] = rng.integers(0, n_classes)
            return
        rec(idx[mask], d - 1)
        rec(idx[~mask], d - 1)

    rec(np.arange(X.shape[0]), depth)
    return y


def _synthetic(
    n: int,
    f: int,
    c: int,
    *,
    planted_depth: int,
    label_noise: float,
    seed: int,
    categorical_levels: Optional[int] = None,
    quantize: Optional[int] = None,
) -> tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(seed)
    if categorical_levels:
        # ordinal-encoded categorical features (Car-style)
        X = rng.integers(0, categorical_levels, size=(n, f)).astype(np.float64)
    else:
        X = rng.uniform(0.0, 1.0, size=(n, f))
    if quantize:
        # integer-valued features (Covid-style: age/sex/region codes) — few
        # distinct values => repeated CART thresholds => narrow LUTs.
        X = np.floor(X * quantize)
    y = _planted_tree_labels(X, c, planted_depth, rng)
    flip = rng.random(n) < label_noise
    y[flip] = rng.integers(0, c, size=int(flip.sum()))
    return X, y


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    n_instances: int       # Table II
    n_features: int        # Table II
    n_classes: int         # Table II
    loader: Callable[[], tuple[np.ndarray, np.ndarray]]
    # CART fit params used by benchmarks to land in the Table V LUT regime
    max_depth: int = 16
    max_leaves: Optional[int] = None
    min_samples_leaf: int = 1
    # paper's Table V LUT size (rows x width), for regime reference
    paper_lut: Optional[tuple[int, int]] = None
    synthetic: bool = True


DATASETS: dict[str, DatasetSpec] = {
    "iris": DatasetSpec(
        "iris", 150, 4, 3, _iris, max_depth=5, paper_lut=(9, 12),
        synthetic=False,
    ),
    "diabetes": DatasetSpec(
        "diabetes", 768, 8, 2,
        lambda: _synthetic(768, 8, 2, planted_depth=6, label_noise=0.18, seed=11),
        max_depth=12, max_leaves=121, paper_lut=(120, 123),
    ),
    "haberman": DatasetSpec(
        "haberman", 306, 3, 2,
        lambda: _synthetic(306, 3, 2, planted_depth=7, label_noise=0.30, seed=12),
        max_depth=14, max_leaves=94, paper_lut=(93, 71),
    ),
    "car": DatasetSpec(
        "car", 1728, 6, 4,
        lambda: _synthetic(
            1728, 6, 4, planted_depth=6, label_noise=0.05, seed=13,
            categorical_levels=4,
        ),
        max_depth=12, max_leaves=77, paper_lut=(76, 20),
    ),
    "cancer": DatasetSpec(
        "cancer", 569, 30, 2,
        lambda: _synthetic(569, 30, 2, planted_depth=4, label_noise=0.05, seed=14),
        max_depth=8, max_leaves=24, paper_lut=(23, 52),
    ),
    "credit": DatasetSpec(
        "credit", 120269, 10, 2,
        lambda: _synthetic(120269, 10, 2, planted_depth=12, label_noise=0.12,
                           seed=15, quantize=400),
        max_depth=40, max_leaves=8476, paper_lut=(8475, 3580),
    ),
    "titanic": DatasetSpec(
        "titanic", 887, 6, 2,
        lambda: _synthetic(887, 6, 2, planted_depth=7, label_noise=0.20, seed=16),
        max_depth=16, max_leaves=192, paper_lut=(191, 150),
    ),
    "covid": DatasetSpec(
        "covid", 33599, 4, 2,
        lambda: _synthetic(33599, 4, 2, planted_depth=9, label_noise=0.015,
                           seed=17, quantize=40),
        max_depth=24, max_leaves=442, paper_lut=(441, 146),
    ),
}


def normalize(X: np.ndarray) -> np.ndarray:
    """Min-max normalize features to [0, 1] (the paper's input-noise study is
    on normalized features)."""
    X = np.asarray(X, dtype=np.float64)
    lo, hi = X.min(axis=0), X.max(axis=0)
    return (X - lo) / np.maximum(hi - lo, 1e-12)


def load(name: str) -> tuple[np.ndarray, np.ndarray]:
    spec = DATASETS[name]
    X, y = spec.loader()
    assert X.shape == (spec.n_instances, spec.n_features), (name, X.shape)
    assert int(y.max()) + 1 <= spec.n_classes
    return X, y


def load_split(
    name: str, *, train_frac: float = 0.9, seed: int = 0, norm: bool = True
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """90/10 split (paper §III), deterministic shuffle, optional min-max norm
    (fitted on the full data, as the paper normalizes the dataset once)."""
    X, y = load(name)
    if norm:
        X = normalize(X)
    rng = np.random.default_rng(seed)
    perm = rng.permutation(X.shape[0])
    n_tr = int(round(train_frac * X.shape[0]))
    tr, te = perm[:n_tr], perm[n_tr:]
    return X[tr], y[tr], X[te], y[te]
