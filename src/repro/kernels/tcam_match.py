"""Pallas TPU kernel: MXU-formulation ternary CAM match with selective
precharge (DESIGN.md §2).

Hardware mapping of the paper's ReCAM array:
  * one column division (width S)  -> one grid step along the innermost
    (sequential) grid axis; TPU grids execute sequentially so the carried
    ``active`` block implements selective precharge *for free*,
  * match-line evaluation          -> two MXU matmuls per division:
    ``mism = X·is0ᵀ + (1-X)·is1ᵀ`` (a don't-care cell sets neither plane and
    contributes nothing — exactly the TCAM semantics),
  * sense-amp threshold            -> ``mism <= kmax[row, division]``
    (kmax = 0 is ideal hardware; per-SA reference-voltage offsets lower to a
    precomputed integer tolerance, keeping the analog model out of the hot
    loop),
  * row-parallel tiles             -> the (batch-block × row-block) grid axes.

Block shapes: X (Bb, S) · is0ᵀ (S, Rb) with Bb = Rb = 128 default — MXU-sized
operands; the S (contraction) dimension is the physical TCAM row width, a
power of two in {16..128} by Table IV, zero-padded to 128 lanes by Mosaic
when smaller.

Outputs are revisited accumulator blocks (index map ignores the sequential
axis), so the carry lives in VMEM without explicit scratch:
  active (B, R) int32 — after the last division: survive mask,
  evals  (B, R) int32 — number of divisions the row was evaluated in.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["tcam_match_pallas"]


def _kernel(x_ref, is0_ref, is1_ref, kmax_ref, active_ref, evals_ref):
    d = pl.program_id(2)

    @pl.when(d == 0)
    def _init():
        active_ref[...] = jnp.ones_like(active_ref)
        evals_ref[...] = jnp.zeros_like(evals_ref)

    x = x_ref[...]                                    # (Bb, S) f32 {0,1}
    # Two MXU matmuls; f32 accumulation is exact (counts <= S).
    mism = jnp.dot(
        x, is0_ref[...].T, preferred_element_type=jnp.float32
    ) + jnp.dot(1.0 - x, is1_ref[...].T, preferred_element_type=jnp.float32)
    match = (mism <= kmax_ref[...].T.astype(jnp.float32)).astype(jnp.int32)

    act = active_ref[...]                             # carried across d
    evals_ref[...] += act                             # active => evaluated
    active_ref[...] = act * match                     # selective precharge


@functools.partial(
    jax.jit, static_argnames=("s", "block_b", "block_r", "interpret")
)
def tcam_match_pallas(
    xbits: jax.Array,           # (B, W) — {0,1}, any dtype
    is0: jax.Array,             # (R, W)
    is1: jax.Array,             # (R, W)
    kmax: jax.Array,            # (R, D) int32  (D = W // s)
    *,
    s: int,
    block_b: int = 128,
    block_r: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (survive (B,R) int32, evals (B,R) int32).  B % block_b == 0,
    R % block_r == 0, W % s == 0 — callers pad via ``ops.tcam_match``."""
    b, w = xbits.shape
    r = is0.shape[0]
    assert w % s == 0 and b % block_b == 0 and r % block_r == 0, (b, r, w, s)
    d = w // s
    assert kmax.shape == (r, d), (kmax.shape, (r, d))

    x = xbits.astype(jnp.float32)
    p0 = is0.astype(jnp.float32)
    p1 = is1.astype(jnp.float32)

    grid = (b // block_b, r // block_r, d)
    survive, evals = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, s), lambda i, j, k: (i, k)),    # x
            pl.BlockSpec((block_r, s), lambda i, j, k: (j, k)),    # is0
            pl.BlockSpec((block_r, s), lambda i, j, k: (j, k)),    # is1
            pl.BlockSpec((block_r, 1), lambda i, j, k: (j, k)),    # kmax
        ],
        out_specs=[
            pl.BlockSpec((block_b, block_r), lambda i, j, k: (i, j)),
            pl.BlockSpec((block_b, block_r), lambda i, j, k: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, r), jnp.int32),
            jax.ShapeDtypeStruct((b, r), jnp.int32),
        ],
        interpret=interpret,
    )(x, p0, p1, kmax.astype(jnp.int32))
    return survive, evals
