"""Pallas TPU kernel: bit-packed ternary CAM match (VPU formulation).

Beyond-paper optimization for the memory-bound regime (DESIGN.md §2): the
MXU kernel streams f32 bitplanes (8 bytes/cell for both planes); this kernel
packs 32 cells into one uint32 word per plane (1/16 the bytes), and replaces
the matmuls with XOR/AND + ``lax.population_count`` on the VPU:

    mism[b, r] = Σ_w popcount((x[b, w] ^ val[r, w]) & care[r, w])

The selective-precharge carry and grid layout are identical to
``tcam_match.py``.  CELL_MM (SAF-induced always-mismatch) is not
representable packed — ``ops.tcam_match`` falls back to the MXU kernel when
the LUT contains MM cells.

The word loop is a static Python unroll (S/32 <= 4 words per division for
Table IV sizes) of (Bb × Rb) broadcast compares — fully vectorized on the
8x128 VPU lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["tcam_match_packed_pallas"]


def _kernel(sw: int, x_ref, val_ref, care_ref, kmax_ref, active_ref, evals_ref):
    d = pl.program_id(2)

    @pl.when(d == 0)
    def _init():
        active_ref[...] = jnp.ones_like(active_ref)
        evals_ref[...] = jnp.zeros_like(evals_ref)

    mism = jnp.zeros(active_ref.shape, jnp.int32)
    for w in range(sw):  # static unroll: S/32 words per division
        xw = x_ref[:, w][:, None]          # (Bb, 1) uint32
        vw = val_ref[:, w][None, :]        # (1, Rb) uint32
        cw = care_ref[:, w][None, :]
        diff = (xw ^ vw) & cw              # (Bb, Rb)
        mism += jax.lax.population_count(diff).astype(jnp.int32)

    match = (mism <= kmax_ref[...].T).astype(jnp.int32)
    act = active_ref[...]
    evals_ref[...] += act
    active_ref[...] = act * match


@functools.partial(
    jax.jit, static_argnames=("s", "block_b", "block_r", "interpret")
)
def tcam_match_packed_pallas(
    xpacked: jax.Array,        # (B, W32) uint32
    val: jax.Array,            # (R, W32) uint32
    care: jax.Array,           # (R, W32) uint32
    kmax: jax.Array,           # (R, D) int32, D = W32 // (s // 32)
    *,
    s: int,                    # division width in bits (multiple of 32)
    block_b: int = 256,
    block_r: int = 256,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    b, w32 = xpacked.shape
    r = val.shape[0]
    assert s % 32 == 0
    sw = s // 32
    assert w32 % sw == 0 and b % block_b == 0 and r % block_r == 0
    d = w32 // sw
    assert kmax.shape == (r, d), (kmax.shape, (r, d))

    grid = (b // block_b, r // block_r, d)
    kern = functools.partial(_kernel, sw)
    survive, evals = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, sw), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_r, sw), lambda i, j, k: (j, k)),
            pl.BlockSpec((block_r, sw), lambda i, j, k: (j, k)),
            pl.BlockSpec((block_r, 1), lambda i, j, k: (j, k)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, block_r), lambda i, j, k: (i, j)),
            pl.BlockSpec((block_b, block_r), lambda i, j, k: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, r), jnp.int32),
            jax.ShapeDtypeStruct((b, r), jnp.int32),
        ],
        interpret=interpret,
    )(xpacked, val, care, kmax.astype(jnp.int32))
    return survive, evals
