"""Banked (multi-array) TCAM match — the ensemble execution hot-spot.

A compiled forest is a set of G banks, each an independent tiled TCAM with its
*own* search-word encoding (each tree has its own thresholds).  Banks in one
execution group share a padded shape (R rows, W = D·S columns, from the
power-of-two bucketing in ``repro.forest.plan``), so the whole group evaluates
as one batched kernel invocation over a leading bank axis:

  mism[g, b, r, d] = Σ_{w∈d} x[g]·is0[g] + (1 - x[g])·is1[g]

with the same selective-precharge cumprod over divisions as the single-bank
kernels (ref.py).  Padding rows carry ``kmax = -1`` (always mismatch) and
padding divisions are all-CELL_X (trivially match, then corrected out of the
activity counts by the caller via ``min(evals, d_real)``).

Engines:
  'banked' — one batched einsum over all banks (default jax path; a single
             XLA kernel invocation for the whole group).
  'mxu'    — ``jax.vmap`` of the Pallas MXU bitplane kernel over the bank
             axis (one pallas_call whose grid covers every bank).
  'ref'    — per-bank python loop over ``tcam_match_ref`` (oracle).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.lut import bitplanes
from .ops import default_interpret
from .ref import tcam_match_ref
from .tcam_match import tcam_match_pallas

__all__ = ["tcam_match_banked", "tcam_match_banked_ref", "BANKED_ENGINES"]

BANKED_ENGINES = ("banked", "mxu", "ref")


@functools.partial(jax.jit, static_argnames=("s",))
def tcam_match_banked_ref(
    xpad: jax.Array,    # (G, B, W) {0,1} search words, per-bank encodings
    is0: jax.Array,     # (G, R, W)
    is1: jax.Array,     # (G, R, W)
    s: int,
    kmax: jax.Array,    # (G, R, D) int32; -1 rows always mismatch
) -> tuple[jax.Array, jax.Array]:
    """Batched-einsum banked match: (survive, evals), both (G, B, R) int32."""
    g, b, w = xpad.shape
    r = is0.shape[1]
    assert w % s == 0, (w, s)
    d = w // s
    x = xpad.astype(jnp.float32).reshape(g, b, d, s)
    p0 = is0.astype(jnp.float32).reshape(g, r, d, s)
    p1 = is1.astype(jnp.float32).reshape(g, r, d, s)
    # (G, B, R, D) mismatch counts, exact in f32 (counts <= S < 2^24)
    mism = jnp.einsum("gbds,grds->gbrd", x, p0) + jnp.einsum(
        "gbds,grds->gbrd", 1.0 - x, p1
    )
    match = mism <= kmax[:, None].astype(jnp.float32)
    if d == 1:
        # single division: every row is evaluated exactly once and survives
        # iff it matches — skip the cumprod (slow XLA constant-fold)
        return (
            match[:, :, :, 0].astype(jnp.int32),
            jnp.ones((g, b, r), jnp.int32),
        )
    prior = jnp.cumprod(
        jnp.concatenate(
            [jnp.ones((g, b, r, 1), bool), match[:, :, :, :-1]], axis=3
        ),
        axis=3,
    )
    survive = (prior[:, :, :, -1] & match[:, :, :, -1]).astype(jnp.int32)
    evals = prior.sum(axis=3).astype(jnp.int32)
    return survive, evals


def _pad_to(a: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = (-a.shape[axis]) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


def tcam_match_banked(
    cells: np.ndarray,            # (G, R, W) int8 stacked bank cell grids
    xpad: jax.Array,              # (G, B, W) per-bank padded search words
    s: int,
    kmax: Optional[jax.Array] = None,   # (G, R, D) int32
    *,
    engine: str = "banked",
    block_b: int = 128,
    block_r: int = 128,
    interpret: Optional[bool] = None,
) -> tuple[jax.Array, jax.Array]:
    """Match a group of same-shape banks in one invocation.

    Returns (survive, evals), both (G, B, R) int32, selective-precharge
    semantics per bank (see module docstring for padding conventions).
    """
    if engine not in BANKED_ENGINES:
        raise ValueError(
            f"unknown banked engine {engine!r}; expected one of {BANKED_ENGINES}"
        )
    interpret = default_interpret() if interpret is None else interpret
    g, r, w = cells.shape
    b = xpad.shape[1]
    assert w % s == 0, (w, s)
    d = w // s
    if kmax is None:
        kmax = jnp.zeros((g, r, d), jnp.int32)
    else:
        kmax = jnp.asarray(kmax).astype(jnp.int32)

    is0np, is1np = bitplanes(np.asarray(cells))
    is0, is1 = jnp.asarray(is0np), jnp.asarray(is1np)
    xpad = jnp.asarray(xpad)

    if engine == "ref":
        outs = [
            tcam_match_ref(xpad[i], is0[i], is1[i], s, kmax[i])
            for i in range(g)
        ]
        survive = jnp.stack([o[0] for o in outs])
        evals = jnp.stack([o[1] for o in outs])
        return survive, evals

    if engine == "banked":
        return tcam_match_banked_ref(xpad, is0, is1, s, kmax)

    # engine == "mxu": vmap the Pallas kernel over the bank axis; pad batch
    # and rows to block multiples (pad rows kmax = -1: always mismatch).
    xp = _pad_to(xpad, 1, block_b)
    i0 = _pad_to(is0, 1, block_r)
    i1 = _pad_to(is1, 1, block_r)
    km = jnp.pad(kmax, ((0, 0), (0, i0.shape[1] - r), (0, 0)),
                 constant_values=-1)
    kernel = functools.partial(
        tcam_match_pallas, s=s, block_b=block_b, block_r=block_r,
        interpret=interpret,
    )
    survive, evals = jax.vmap(kernel)(xp, i0, i1, km)
    return survive[:, :b, :r], evals[:, :b, :r]
