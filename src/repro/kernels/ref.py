"""Pure-jnp oracles for the TCAM kernels.

Semantics (shared by both kernels, see DESIGN.md §2):

Given encoded search words ``x ∈ {0,1}^{B×W}`` (decoder bit included, padded
to W = n_cwd·S), bitplanes ``is0, is1 ∈ {0,1}^{R×W}`` (CELL_X sets neither,
CELL_MM sets both) and a per-(row, division) mismatch tolerance
``kmax ∈ ℤ^{R×D}`` (0 = ideal hardware; >0 models SA reference-voltage
offsets that would sense a near-match as a match):

  for each column division d (width S, sequential — selective precharge):
    mism[b, r, d]  = Σ_{w∈d} x·is0 + (1-x)·is1
    match[b, r, d] = mism[b, r, d] <= kmax[r, d]
    a row is *active* in division d iff it matched all previous divisions;
    an *active evaluation* is (row, division) pair with the row active.

Returns:
  survive (B, R) int32 — 1 iff the row matched every division,
  evals   (B, R) int32 — number of divisions the row was evaluated in
                          (∈ [1, D]; this drives the energy model).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["tcam_match_ref", "tcam_match_packed_ref", "pack_bits"]


def tcam_match_ref(
    xbits: jax.Array,   # (B, W) any int/float dtype with {0,1} values
    is0: jax.Array,     # (R, W)
    is1: jax.Array,     # (R, W)
    s: int,             # column-division width (tile edge S)
    kmax: jax.Array | None = None,   # (R, D) int32, default ideal (zeros)
) -> tuple[jax.Array, jax.Array]:
    b, w = xbits.shape
    r = is0.shape[0]
    assert w % s == 0, (w, s)
    d = w // s
    x = xbits.astype(jnp.float32).reshape(b, d, s)
    p0 = is0.astype(jnp.float32).reshape(r, d, s)
    p1 = is1.astype(jnp.float32).reshape(r, d, s)
    # (B, R, D) mismatch counts, exact in f32 (counts <= S < 2^24)
    mism = jnp.einsum("bds,rds->brd", x, p0) + jnp.einsum(
        "bds,rds->brd", 1.0 - x, p1
    )
    if kmax is None:
        kmax = jnp.zeros((r, d), jnp.int32)
    match = mism <= kmax[None].astype(jnp.float32)
    # active in division j iff matched divisions 0..j-1
    prior = jnp.cumprod(
        jnp.concatenate([jnp.ones((b, r, 1), bool), match[:, :, :-1]], axis=2),
        axis=2,
    )
    survive = (prior[:, :, -1] & match[:, :, -1]).astype(jnp.int32)
    evals = prior.sum(axis=2).astype(jnp.int32)
    return survive, evals


def pack_bits(bits: jax.Array) -> jax.Array:
    """Pack a (..., W) array of {0,1} into (..., W//32) uint32, little-endian
    within each word (bit i of word j = column 32*j + i).  W % 32 == 0."""
    *lead, w = bits.shape
    assert w % 32 == 0, w
    b = bits.astype(jnp.uint32).reshape(*lead, w // 32, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return (b << shifts).sum(axis=-1).astype(jnp.uint32)


def tcam_match_packed_ref(
    xpacked: jax.Array,   # (B, W32) uint32
    val: jax.Array,       # (R, W32) uint32 — packed is1 (stored bit values)
    care: jax.Array,      # (R, W32) uint32 — packed (is0 | is1)
    s: int,               # division width in BITS (multiple of 32)
    kmax: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Packed-domain oracle.  A cell mismatches iff care-bit set and the input
    bit differs from the value bit: popcount((x ^ val) & care).

    CELL_MM (both planes set) is *not representable* in packed form — packed
    kernels are for defect-free LUTs (ideal or SA-variability studies); the
    unpacked kernel handles SAF-injected cells.
    """
    b, w32 = xpacked.shape
    r = val.shape[0]
    assert s % 32 == 0
    sw = s // 32
    assert w32 % sw == 0
    d = w32 // sw
    xw = xpacked.reshape(b, d, sw)
    vw = val.reshape(r, d, sw)
    cw = care.reshape(r, d, sw)
    diff = (xw[:, None] ^ vw[None]) & cw[None]          # (B, R, D, SW)
    mism = jax.lax.population_count(diff).astype(jnp.int32).sum(axis=-1)
    if kmax is None:
        kmax = jnp.zeros((r, d), jnp.int32)
    match = mism <= kmax[None]
    prior = jnp.cumprod(
        jnp.concatenate([jnp.ones((b, r, 1), bool), match[:, :, :-1]], axis=2),
        axis=2,
    )
    survive = (prior[:, :, -1] & match[:, :, -1]).astype(jnp.int32)
    evals = prior.sum(axis=2).astype(jnp.int32)
    return survive, evals
