"""Pallas TPU kernels for the paper's compute hot-spot: the massively
parallel ternary match (TCAM search).  See DESIGN.md §2 for the
analog-ReCAM -> TPU mapping.

  tcam_match.py  — MXU bitplane-matmul kernel, grid-sequential selective
                   precharge (handles all cell states incl. SAF CELL_MM)
  tcam_packed.py — bit-packed XOR/AND/popcount VPU kernel (16x fewer bytes)
  ops.py         — engine selection, padding, SA-variability lowering,
                   jit'd serving path
  ref.py         — pure-jnp oracles both kernels are validated against
  banked.py      — multi-bank (ensemble) batched/vmapped match
"""
from .banked import BANKED_ENGINES, tcam_match_banked, tcam_match_banked_ref
from .ops import (ENGINES, default_interpret, finalize_result, sa_kmax,
                  select_engine, tcam_infer, tcam_match)
from .ref import pack_bits, tcam_match_packed_ref, tcam_match_ref
from .tcam_match import tcam_match_pallas
from .tcam_packed import tcam_match_packed_pallas

__all__ = [
    "ENGINES", "default_interpret", "finalize_result", "sa_kmax",
    "select_engine", "tcam_infer", "tcam_match",
    "pack_bits", "tcam_match_packed_ref", "tcam_match_ref",
    "tcam_match_pallas", "tcam_match_packed_pallas",
    "BANKED_ENGINES", "tcam_match_banked", "tcam_match_banked_ref",
]
