"""Public jit'd TCAM-match ops: engine selection, padding, packing, and the
JAX serving path (`tcam_infer`) that the examples / serving stack use.

Engines:
  'mxu'    — float bitplane matmul kernel (tcam_match.py); handles every cell
             state incl. SAF-induced CELL_MM.
  'packed' — bit-packed popcount kernel (tcam_packed.py); 16x fewer HBM bytes;
             requires S % 32 == 0 and no CELL_MM cells.
  'ref'    — pure-jnp oracle (ref.py).
  'auto'   — packed when legal, else mxu.

All engines share the contract: inputs are the *padded search words* from
``TCAMLayout.pad_inputs`` (decoder bit + encoded features + padding) and the
layout's cell grid; outputs are (survive, evals) as defined in ref.py.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.energy import DEFAULT_HW, HardwareParams, f_max, t_cwd
from ..core.lut import CELL_MM, bitplanes
from ..core.simulate import SimResult, sense_voltage
from ..core.synth import TCAMLayout
from .ref import pack_bits, tcam_match_packed_ref, tcam_match_ref
from .tcam_match import tcam_match_pallas
from .tcam_packed import tcam_match_packed_pallas

__all__ = ["tcam_match", "tcam_infer", "sa_kmax", "select_engine",
           "finalize_result", "default_interpret", "ENGINES"]

ENGINES = ("auto", "mxu", "packed", "ref")


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def select_engine(cells: np.ndarray, s: int, engine: str = "auto") -> str:
    """Resolve an engine request against the layout's legality constraints.

    'auto' picks 'packed' (16x fewer HBM bytes) when legal — S % 32 == 0 and
    no SAF-induced CELL_MM cells (unrepresentable in packed bitplanes) — else
    'mxu'.  An explicit illegal 'packed' request raises.
    """
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; expected one of {ENGINES}")
    has_mm = bool(np.any(np.asarray(cells) == CELL_MM))
    packed_ok = s % 32 == 0 and not has_mm
    if engine == "auto":
        return "packed" if packed_ok else "mxu"
    if engine == "packed" and not packed_ok:
        raise ValueError("packed engine needs S % 32 == 0 and no CELL_MM cells")
    return engine


def _pad_to(a: jax.Array, axis: int, mult: int) -> jax.Array:
    n = a.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


def tcam_match(
    cells: np.ndarray,            # (R, W) int8 cell states (layout.cells)
    xpad: jax.Array,              # (B, W) padded search words {0,1}
    s: int,
    kmax: Optional[jax.Array] = None,   # (R, D) int32
    *,
    engine: str = "auto",
    block_b: int = 128,
    block_r: int = 128,
    interpret: Optional[bool] = None,
) -> tuple[jax.Array, jax.Array]:
    """Match search words against a tiled TCAM; returns (survive, evals),
    both (B, R) int32, selective-precharge semantics (see ref.py)."""
    interpret = default_interpret() if interpret is None else interpret
    r, w = cells.shape
    b = xpad.shape[0]
    d = w // s
    assert w % s == 0
    engine = select_engine(cells, s, engine)

    kmax = jnp.zeros((r, d), jnp.int32) if kmax is None else kmax.astype(jnp.int32)
    is0np, is1np = bitplanes(np.asarray(cells))

    if engine == "ref":
        surv, ev = tcam_match_ref(xpad, jnp.asarray(is0np), jnp.asarray(is1np),
                                  s, kmax)
        return surv, ev

    # pad batch and rows to block multiples; padded kmax = -1 so pad rows
    # mismatch immediately (sliced away anyway).
    xp = _pad_to(jnp.asarray(xpad), 0, block_b)
    is0 = _pad_to(jnp.asarray(is0np), 0, block_r)
    is1 = _pad_to(jnp.asarray(is1np), 0, block_r)
    km = jnp.pad(kmax, ((0, is0.shape[0] - r), (0, 0)), constant_values=-1)

    if engine == "packed":
        xq = pack_bits(xp)
        val = pack_bits(is1)
        care = pack_bits(jnp.asarray(is0np | is1np))
        care = _pad_to(care, 0, block_r)
        surv, ev = tcam_match_packed_pallas(
            xq, val, care, km, s=s,
            block_b=block_b, block_r=block_r, interpret=interpret,
        )
    elif engine == "mxu":
        surv, ev = tcam_match_pallas(
            xp, is0, is1, km, s=s,
            block_b=block_b, block_r=block_r, interpret=interpret,
        )
    else:
        raise ValueError(f"unknown engine {engine!r}")
    return surv[:b, :r], ev[:b, :r]


def sa_kmax(
    layout: TCAMLayout,
    sa_offsets: np.ndarray,       # (R, D) sampled SA V_ref offsets
    hw: HardwareParams = DEFAULT_HW,
) -> np.ndarray:
    """Lower analog SA-variability to an integer mismatch tolerance:
    row r (division d) senses 'match' iff V_ml(mism) > V_ref(d) + offset[r,d];
    V_ml is monotone decreasing in the mismatch count, so the analog decision
    equals ``mism <= kmax[r, d]`` with kmax = #{k : V(k) > thresh} - 1.

    kmax = -1 encodes 'always mismatch' (offset pushed V_ref above V_fm);
    ideal hardware is kmax = 0 everywhere.
    """
    s, n_cwd = layout.s, layout.n_cwd
    rows = layout.cells.shape[0]
    used = 1 + layout.width
    n_eff = np.array(
        [max(0, min((d + 1) * s, used) - d * s) for d in range(n_cwd)], np.int64
    )
    # V(k) for k = 0..S per division (n_eff varies only in the last division)
    ks = np.arange(s + 1)
    kmax = np.zeros((rows, n_cwd), np.int64)
    for d_i in range(n_cwd):
        if n_eff[d_i] == 0:
            kmax[:, d_i] = s  # fully masked division: always matches
            continue
        v = sense_voltage(ks, np.full_like(ks, n_eff[d_i]), s, hw)  # (S+1,)
        v_fm = v[0]
        v_1mm = sense_voltage(np.array([1]), np.array([n_eff[d_i]]), s, hw)[0]
        v_ref = 0.5 * (v_fm + v_1mm)
        thresh = v_ref + sa_offsets[:, d_i]          # (R,)
        kmax[:, d_i] = (v[None, :] > thresh[:, None]).sum(axis=1) - 1
    return kmax.astype(np.int32)


@jax.jit
def _finalize(survive, evals, classes):
    n_survivors = survive.sum(axis=1).astype(jnp.int32)
    first = jnp.argmax(survive, axis=1).astype(jnp.int32)
    survivors = jnp.where(n_survivors > 0, first, -1)
    preds = jnp.where(n_survivors > 0, classes[jnp.maximum(survivors, 0)], 0)
    active_evals = evals.sum(axis=1)
    return preds.astype(jnp.int32), survivors, n_survivors, active_evals


def finalize_result(
    layout: TCAMLayout,
    preds: np.ndarray,
    survivors: np.ndarray,
    n_survivors: np.ndarray,
    active_evals: np.ndarray,
    *,
    hw: HardwareParams = DEFAULT_HW,
    selective_precharge: bool = True,
) -> SimResult:
    """Assemble the kernel outputs into a ``SimResult``.

    Energy/latency/throughput use the exact float64 formulas of the numpy
    oracle (``core.simulate.simulate``) on the integer activity counts, so the
    JAX path is bit-identical to the oracle on ideal hardware — not merely
    numerically close.
    """
    b = preds.shape[0]
    if selective_precharge:
        active = np.asarray(active_evals).astype(np.int64)
    else:
        active = np.full(b, layout.cells.shape[0] * layout.n_cwd, np.int64)
    energy = active.astype(np.float64) * hw.e_row + hw.e_mem
    fm = f_max(layout.s, hw)
    return SimResult(
        predictions=np.asarray(preds).astype(np.int32),
        survivors=np.asarray(survivors).astype(np.int32),
        n_survivors=np.asarray(n_survivors).astype(np.int32),
        active_evals=active,
        energy_per_dec=energy,
        latency_s=layout.n_cwd * t_cwd(layout.s, hw) + hw.t_mem,
        throughput_seq=fm / layout.n_cwd,
        throughput_pipe=fm / hw.pipeline_ii_cycles,
        s=layout.s,
        n_cwd=layout.n_cwd,
        n_rwd=layout.n_rwd,
    )


def tcam_infer(
    layout: TCAMLayout,
    xbits: np.ndarray,
    *,
    hw: HardwareParams = DEFAULT_HW,
    kmax: Optional[np.ndarray] = None,
    engine: str = "auto",
    selective_precharge: bool = True,
    interpret: Optional[bool] = None,
) -> SimResult:
    """JAX serving path: encoded inputs -> ``SimResult``.  Functionally
    identical to ``core.simulate.simulate`` (tested bit-exact) but runs the
    match on the Pallas kernels.

    .. versionchanged:: 0.8
       This once returned a bare 5-tuple and the returned ``SimResult`` kept
       a one-release tuple-unpacking shim; the shim has expired — use the
       named fields.
    """
    xpad = jnp.asarray(layout.pad_inputs(np.asarray(xbits, np.uint8)))
    km = None if kmax is None else jnp.asarray(kmax)
    survive, evals = tcam_match(
        layout.cells, xpad, layout.s, km, engine=engine, interpret=interpret
    )
    preds, survivors, n_survivors, active = _finalize(
        survive, evals, jnp.asarray(layout.classes)
    )
    return finalize_result(
        layout, preds, survivors, n_survivors, active,
        hw=hw, selective_precharge=selective_precharge,
    )
