"""DT2CAM reproduction — blessed public API.

Import policy (see README "Import policy"): user code — examples, benchmarks,
notebooks, downstream services — imports from **this** module (or the stable
sub-packages ``repro.core``, ``repro.forest``, ``repro.serve``, ``repro.dt``,
``repro.degradation``), never from deep module paths like
``repro.core.compiler`` or ``repro.serve.engine``.  Deep paths are
implementation detail and move without deprecation; everything in ``__all__``
below is covered by the one-release deprecation policy.

Single tree:

    >>> import repro
    >>> model = repro.DT2CAM(s=128).fit(X, y)
    >>> res = model.infer(Xq)                       # numpy oracle
    >>> res = model.infer(Xq, backend="jax")        # Pallas kernels

Forest (multi-bank):

    >>> forest = repro.compile_forest(sklearn_rf, s=128)
    >>> res = repro.forest_infer_ref(forest, Xq)    # numpy oracle
    >>> ex = repro.ForestExecutor(forest)           # banked jax execution
    >>> res = ex.infer(Xq)

Serving (both single- and multi-bank models):

    >>> with repro.TCAMServer(compiled) as srv:
    ...     preds = [r.prediction for r in srv.serve(Xq)]

Model lifecycle (versioned registry, delta reprogramming, hot swap):

    >>> reg = repro.ModelRegistry("artifacts/registry")
    >>> v1 = reg.publish(model.compiled, "traffic")
    >>> mgr = repro.LifecycleManager(reg, srv, live_version=v1.version_id)
    >>> mgr.stage(v2.version_id); ...; mgr.promote(max_disagreement=0.05)

Everything importable eagerly here is numpy-only; jax-dependent names
(``TCAMServer``, ``ForestExecutor``, the kernel entry points) load on first
access via module ``__getattr__``.
"""
from .core import (
    CELL_0,
    CELL_1,
    CELL_MM,
    CELL_X,
    DEFAULT_HW,
    DT2CAM,
    IDEAL,
    CompiledDT,
    DecisionTree,
    DriftModel,
    DriftSpec,
    FeatureMismatch,
    HardwareParams,
    NonIdealSpec,
    RuleTable,
    SAFMask,
    SenseMargins,
    SimResult,
    TCAMLayout,
    TernaryLUT,
    bank_figures,
    check_feature_count,
    compile_tree,
    encode_inputs,
    encode_table,
    forest_figures,
    mismatch_probability,
    reduce_tree,
    sample_drift,
    sensing_margins,
    simulate,
    synthesize,
    train_tree,
)
from .degradation import (
    ScrubPolicy,
    ScrubReport,
    ScrubScheduler,
    layout_margins,
    plan_refresh,
)
from .dt import DATASETS, load, load_split, normalize
from .lifecycle import (
    LifecycleManager,
    ModelRegistry,
    ModelVersion,
    RemapResult,
    WearTracker,
    WritePlan,
    content_hash,
    plan_delta,
    plan_forest_delta,
    plan_full,
    wear_level_rows,
)
from .forest import (
    CompiledForest,
    ForestBank,
    ForestPlan,
    ForestResult,
    aggregate_votes,
    compile_forest,
    forest_infer_ref,
    plan_forest,
    train_forest,
)

__all__ = [
    # core: compile + simulate
    "DT2CAM", "CompiledDT", "compile_tree", "DecisionTree", "train_tree",
    "RuleTable", "reduce_tree", "encode_table", "encode_inputs",
    "TernaryLUT", "TCAMLayout", "synthesize", "simulate", "SimResult",
    "CELL_0", "CELL_1", "CELL_X", "CELL_MM",
    # validation + non-idealities
    "FeatureMismatch", "check_feature_count",
    "NonIdealSpec", "IDEAL", "SAFMask",
    "DriftSpec", "DriftModel", "sample_drift",
    # hardware model
    "HardwareParams", "DEFAULT_HW", "bank_figures", "forest_figures",
    "SenseMargins", "sensing_margins", "mismatch_probability",
    # degradation: scrub-and-refresh scheduling
    "ScrubPolicy", "ScrubReport", "ScrubScheduler",
    "plan_refresh", "layout_margins",
    # forests
    "CompiledForest", "ForestBank", "ForestResult", "compile_forest",
    "train_forest", "forest_infer_ref", "aggregate_votes",
    "ForestPlan", "plan_forest",
    # datasets
    "DATASETS", "load", "load_split", "normalize",
    # lifecycle: registry + delta reprogramming + wear
    "ModelRegistry", "ModelVersion", "content_hash",
    "WritePlan", "plan_delta", "plan_full", "plan_forest_delta",
    "WearTracker", "RemapResult", "wear_level_rows", "LifecycleManager",
    # jax-dependent (lazy): kernels
    "tcam_infer", "tcam_match", "tcam_match_banked", "ENGINES",
    "BANKED_ENGINES", "select_engine", "finalize_result",
    # jax-dependent (lazy): executors + serving
    "ForestExecutor", "FOREST_ENGINES",
    "TCAMServer", "ServeConfig", "RequestResult", "PromotionReport",
    "ServingError", "Rejected", "DeadlineExceeded", "ComputeFailed",
]

_LAZY = {
    "tcam_infer": "kernels",
    "tcam_match": "kernels",
    "tcam_match_banked": "kernels",
    "ENGINES": "kernels",
    "BANKED_ENGINES": "kernels",
    "select_engine": "kernels",
    "finalize_result": "kernels",
    "ForestExecutor": "forest",
    "FOREST_ENGINES": "forest",
    "TCAMServer": "serve",
    "ServeConfig": "serve",
    "RequestResult": "serve",
    "PromotionReport": "serve",
    "ServingError": "serve",
    "Rejected": "serve",
    "DeadlineExceeded": "serve",
    "ComputeFailed": "serve",
}


def __getattr__(name: str):
    pkg = _LAZY.get(name)
    if pkg is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(f".{pkg}", __name__), name)


def __dir__():
    return sorted(set(globals()) | set(__all__))
