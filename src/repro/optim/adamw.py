"""AdamW with decoupled weight decay, global-norm clipping and a cosine LR
schedule.  Moments are f32 pytrees sharded identically to the params (the
2D FSDP×TP layout shards optimizer state for free — ZeRO-equivalent).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "OptState", "adamw_init", "adamw_update",
           "cosine_schedule", "global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    # memory policy: bf16 first moment halves optimizer HBM for 100B+ models
    # on 16GB chips (8-bit-Adam-style compromise; see DESIGN.md §5)
    mu_dtype: str = "float32"
    nu_dtype: str = "float32"


class OptState(NamedTuple):
    mu: dict
    nu: dict
    step: jax.Array


def adamw_init(params, cfg: AdamWConfig = AdamWConfig()) -> OptState:
    zeros = lambda dt: lambda p: jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.dtype(dt)), p)
    return OptState(mu=zeros(cfg.mu_dtype)(params),
                    nu=zeros(cfg.nu_dtype)(params),
                    step=jnp.zeros((), jnp.int32))


def cosine_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (s - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(s < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree.leaves(tree)))


def adamw_update(grads, state: OptState, params, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = cosine_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        mf = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        vf = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = mf / b1c
        vhat = vf / b2c
        pf = p.astype(jnp.float32)
        pf = pf - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                        + cfg.weight_decay * pf)
        return pf.astype(p.dtype), mf.astype(m.dtype), vf.astype(v.dtype)

    flat = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], flat,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], flat,
                          is_leaf=lambda x: isinstance(x, tuple))
    return new_params, OptState(new_mu, new_nu, step), {
        "grad_norm": gnorm, "lr": lr}
