"""Optimizer substrate: AdamW, schedules, clipping, int8+EF compression."""
from .adamw import (
    AdamWConfig,
    OptState,
    adamw_init,
    adamw_update,
    cosine_schedule,
    global_norm,
)
from .compress import dequantize_int8, ef_compress, quantize_int8

__all__ = [
    "AdamWConfig", "OptState", "adamw_init", "adamw_update",
    "cosine_schedule", "global_norm",
    "dequantize_int8", "ef_compress", "quantize_int8",
]
