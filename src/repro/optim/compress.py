"""Gradient compression: per-tensor symmetric int8 quantization with error
feedback (EF / memory-compensated SGD).

On real multi-pod meshes the quantize/dequantize pair wraps the gradient
reduce-scatter at the pod boundary (8x fewer DCN bytes); the EF residual
carries the quantization error into the next step so convergence is
preserved (Stich et al., Karimireddy et al.).

This module is the algorithmic layer: ``ef_compress`` runs in-graph and is
exercised by the train-step flag ``compress="int8_ef"`` plus unit/property
tests; wire-level integration is the documented extension point
(DESIGN.md §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["quantize_int8", "dequantize_int8", "ef_compress", "ef_init"]


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8: returns (q int8, scale f32)."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf)) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_init(params) -> dict:
    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)


def ef_compress(grads, residual):
    """Error-feedback compression: g' = Q(g + r); r' = (g + r) - g'.
    Returns (compressed grads, new residual)."""
    def one(g, r):
        x = g.astype(jnp.float32) + r
        q, s = quantize_int8(x)
        d = dequantize_int8(q, s)
        return d, x - d

    pairs = jax.tree.map(one, grads, residual)
    g_out = jax.tree.map(lambda t: t[0], pairs,
                         is_leaf=lambda x: isinstance(x, tuple))
    r_out = jax.tree.map(lambda t: t[1], pairs,
                         is_leaf=lambda x: isinstance(x, tuple))
    return g_out, r_out
