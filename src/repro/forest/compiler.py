"""Forest -> multi-bank TCAM compiler (numpy-only front half).

``compile_forest`` lowers every tree of an ensemble through the existing
single-tree pipeline (``compile_tree``: reduce -> encode -> synthesize) into
one ``ForestBank`` per tree — each bank an independent tiled ``TCAMLayout``
with its own input encoding — plus the voting metadata needed to aggregate
per-bank matches into an ensemble decision:

* ``vote='soft'`` (sklearn default): per-leaf class-probability tables in
  LUT-row order; votes accumulate in estimator order and reproduce
  ``RandomForestClassifier.predict`` bit-exactly (including sklearn's
  float32 input cast, recorded as ``cast_f32``).
* ``vote='hard'`` (native CART default): one class vote per bank, argmax
  with ties to the lowest class index.

``forest_infer_ref`` is the pure-numpy reference executor (one
``core.simulate`` pass per bank); the batched/vmapped JAX paths live in
``repro.forest.executor`` and are validated against it bit-exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

import numpy as np

from ..core.cart import DecisionTree, train_tree
from ..core.compiler import CompiledDT, check_feature_count, compile_tree
from ..core.encode import encode_inputs
from ..core.energy import DEFAULT_HW, HardwareParams, forest_figures
from ..core.simulate import simulate
from .sklearn_io import from_sklearn_tree, is_sklearn_forest, leaf_proba_rows

__all__ = [
    "ForestBank", "CompiledForest", "ForestResult", "compile_forest",
    "train_forest", "aggregate_votes", "forest_infer_ref", "VOTES",
]

VOTES = ("soft", "hard")


@dataclasses.dataclass
class ForestBank:
    """One tree of the ensemble, compiled onto its own TCAM bank."""

    compiled: CompiledDT
    proba: Optional[np.ndarray] = None  # (n_rows, n_classes) f64, soft vote

    @property
    def layout(self):
        return self.compiled.layout

    @property
    def lut(self):
        return self.compiled.lut


@dataclasses.dataclass
class CompiledForest:
    """A compiled ensemble: per-tree banks + vote aggregation metadata.

    ``classes`` maps internal class indices to output labels (sklearn's
    ``classes_``, or ``arange(n_classes)`` for native trees); ``cast_f32``
    records whether inputs must round-trip through float32 before encoding
    (sklearn does this inside ``predict`` — required for bit-exact parity).
    """

    banks: list[ForestBank]
    n_features: int
    n_classes: int
    classes: np.ndarray
    vote: str
    cast_f32: bool
    s: int

    @property
    def n_banks(self) -> int:
        return len(self.banks)

    @property
    def layouts(self) -> list:
        return [b.layout for b in self.banks]

    def prepare_inputs(self, X: np.ndarray, *,
                       who: str = "forest.infer") -> np.ndarray:
        """Validate the feature count and apply the recorded input cast."""
        X = check_feature_count(X, self.n_features, who=who)
        if self.cast_f32:
            X = X.astype(np.float32).astype(np.float64)
        return X


@dataclasses.dataclass
class ForestResult:
    """Ensemble inference outcome + per-bank activity trace.

    ``score`` is the sklearn-averaged probability matrix (soft vote,
    float64) or the integer vote-count matrix (hard vote), in internal class
    index space; ``predictions`` are already mapped through ``classes``.
    """

    predictions: np.ndarray     # (batch,) output labels
    score: np.ndarray           # (batch, n_classes)
    survivors: np.ndarray       # (n_banks, batch) int32 row index, -1 none
    n_survivors: np.ndarray     # (n_banks, batch) int32
    active_evals: np.ndarray    # (n_banks, batch) int64
    enabled: np.ndarray         # (n_banks,) bool — banks that voted
    engine: str
    figures: dict               # per-bank + aggregate pipelined figures

    @property
    def total_active_evals(self) -> np.ndarray:
        return self.active_evals[self.enabled].sum(axis=0)

    def accuracy(self, labels: np.ndarray) -> float:
        return float((self.predictions == np.asarray(labels)).mean())


def _compile_native(
    trees: Sequence[DecisionTree], s: int, *, seed: int, spare_rows: int,
    nan_full_dontcare: bool,
) -> list[ForestBank]:
    banks = []
    for i, tree in enumerate(trees):
        banks.append(ForestBank(compiled=compile_tree(
            tree, s, nan_full_dontcare=nan_full_dontcare,
            seed=seed + i, spare_rows=spare_rows,
        )))
    return banks


def compile_forest(
    model: Union[Sequence[DecisionTree], object],
    s: int = 128,
    *,
    vote: Optional[str] = None,
    seed: int = 0,
    spare_rows: int = 0,
    nan_full_dontcare: bool = True,
) -> CompiledForest:
    """Compile an ensemble — a sequence of native ``DecisionTree``s or a
    fitted ``sklearn.ensemble.RandomForestClassifier`` — into per-bank TCAM
    layouts plus vote metadata.

    ``vote`` defaults to 'soft' for sklearn forests (matching
    ``RandomForestClassifier.predict``) and 'hard' for native trees.
    Each bank gets ``seed + bank_index`` for its rogue-row synthesis.
    """
    if vote is not None and vote not in VOTES:
        raise ValueError(f"unknown vote {vote!r}; expected one of {VOTES}")

    if is_sklearn_forest(model):
        estimators = list(model.estimators_)
        if not estimators:
            raise ValueError("sklearn forest has no estimators")
        trees = [from_sklearn_tree(e) for e in estimators]
        banks = _compile_native(
            trees, s, seed=seed, spare_rows=spare_rows,
            nan_full_dontcare=nan_full_dontcare,
        )
        for bank, est, tree in zip(banks, estimators, trees):
            bank.proba = leaf_proba_rows(est, tree)
        classes = np.asarray(model.classes_)
        return CompiledForest(
            banks=banks,
            n_features=trees[0].n_features,
            n_classes=len(classes),
            classes=classes,
            vote=vote or "soft",
            cast_f32=True,
            s=s,
        )

    trees = list(model)
    if not trees:
        raise ValueError("compile_forest needs at least one tree")
    if not all(isinstance(t, DecisionTree) for t in trees):
        raise TypeError(
            "compile_forest expects a fitted sklearn RandomForestClassifier "
            "or a sequence of repro DecisionTree objects, got "
            f"{type(trees[0]).__name__}"
        )
    n_features = trees[0].n_features
    if any(t.n_features != n_features for t in trees):
        raise ValueError("all trees must share the same feature count")
    n_classes = max(t.n_classes for t in trees)
    banks = _compile_native(
        trees, s, seed=seed, spare_rows=spare_rows,
        nan_full_dontcare=nan_full_dontcare,
    )
    if (vote or "hard") == "soft":
        # native trees have no proba tables: soft vote degenerates to
        # one-hot leaf distributions (== hard vote with mean instead of sum)
        for bank in banks:
            cls = bank.lut.classes
            onehot = np.zeros((len(cls), n_classes), np.float64)
            onehot[np.arange(len(cls)), cls] = 1.0
            bank.proba = onehot
    return CompiledForest(
        banks=banks,
        n_features=n_features,
        n_classes=n_classes,
        classes=np.arange(n_classes),
        vote=vote or "hard",
        cast_f32=False,
        s=s,
    )


def train_forest(
    X: np.ndarray,
    y: np.ndarray,
    n_trees: int = 25,
    *,
    max_depth: int = 12,
    min_samples_leaf: int = 1,
    bootstrap: bool = True,
    seed: int = 0,
) -> list[DecisionTree]:
    """Bagged CART ensemble on the native trainer (no sklearn needed)."""
    X = np.asarray(X, dtype=np.float64)
    y = np.asarray(y, dtype=np.int64)
    rng = np.random.default_rng(seed)
    n = X.shape[0]
    trees = []
    for _ in range(n_trees):
        idx = rng.integers(0, n, size=n) if bootstrap else np.arange(n)
        trees.append(train_tree(
            X[idx], y[idx], max_depth=max_depth,
            min_samples_leaf=min_samples_leaf,
        ))
    return trees


def aggregate_votes(
    forest: CompiledForest,
    survivors: np.ndarray,          # (n_banks, batch) int32, -1 = no match
    enabled: Optional[np.ndarray] = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Aggregate per-bank surviving rows into ensemble predictions.

    Soft vote replicates sklearn exactly: probabilities accumulate bank by
    bank *in estimator order* (float64 addition is not associative), the sum
    divides by the number of voting banks, and argmax breaks ties toward the
    lower class index.  Hard vote counts one vote per bank.  ``enabled``
    masks out banks (BIST/repair degradation): a dead bank drops out of both
    the accumulation and the divisor, degrading the vote instead of the chip.

    Returns ``(predictions, score)``.
    """
    survivors = np.asarray(survivors)
    n_banks, batch = survivors.shape
    if n_banks != forest.n_banks:
        raise ValueError(
            f"survivors has {n_banks} banks; forest has {forest.n_banks}"
        )
    if enabled is None:
        enabled = np.ones(n_banks, dtype=bool)
    enabled = np.asarray(enabled, dtype=bool)
    n_voting = int(enabled.sum())
    if n_voting == 0:
        raise ValueError("no enabled banks to vote")

    if forest.vote == "soft":
        acc = np.zeros((batch, forest.n_classes), dtype=np.float64)
        for b in range(n_banks):
            if not enabled[b]:
                continue
            rows = survivors[b]
            proba = forest.banks[b].proba
            assert proba is not None, "soft vote needs per-bank proba tables"
            contrib = proba[np.maximum(rows, 0)]
            contrib[rows < 0] = 0.0
            acc += contrib
        score = acc / n_voting
        idx = np.argmax(score, axis=1)
    else:
        score = np.zeros((batch, forest.n_classes), dtype=np.int64)
        cols = np.arange(batch)
        for b in range(n_banks):
            if not enabled[b]:
                continue
            rows = survivors[b]
            valid = rows >= 0
            cls = forest.banks[b].layout.classes[np.maximum(rows, 0)]
            np.add.at(score, (cols[valid], cls[valid]), 1)
        idx = np.argmax(score, axis=1)
    predictions = np.asarray(forest.classes)[idx]
    return predictions, score


def forest_infer_ref(
    forest: CompiledForest,
    X: np.ndarray,
    *,
    hw: HardwareParams = DEFAULT_HW,
    selective_precharge: bool = True,
    enabled: Optional[np.ndarray] = None,
) -> ForestResult:
    """Pure-numpy reference executor: one oracle simulation per bank,
    then vote aggregation.  The JAX paths are validated against this."""
    Xp = forest.prepare_inputs(X, who="forest_infer_ref")
    b = Xp.shape[0]
    survivors = np.empty((forest.n_banks, b), np.int32)
    n_survivors = np.empty((forest.n_banks, b), np.int32)
    active = np.empty((forest.n_banks, b), np.int64)
    for i, bank in enumerate(forest.banks):
        xbits = encode_inputs(bank.lut, Xp)
        res = simulate(
            bank.layout, xbits, hw=hw,
            selective_precharge=selective_precharge,
        )
        survivors[i] = res.survivors
        n_survivors[i] = res.n_survivors
        active[i] = res.active_evals
    predictions, score = aggregate_votes(forest, survivors, enabled)
    en = (np.ones(forest.n_banks, bool) if enabled is None
          else np.asarray(enabled, bool))
    figures = forest_figures(
        forest.layouts, hw,
        mean_active_evals=[float(a.mean()) for a in active],
    )
    return ForestResult(
        predictions=predictions,
        score=score,
        survivors=survivors,
        n_survivors=n_survivors,
        active_evals=active,
        enabled=en,
        engine="ref",
        figures=figures,
    )
