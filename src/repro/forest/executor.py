"""Sharded multi-bank forest executor (JAX paths).

Runs a compiled forest's execution plan on the banked kernels: every
``PlanGroup`` evaluates as ONE batched/vmapped kernel invocation (engine
'banked' = batched einsum, 'mxu' = vmapped Pallas bitplane kernel), with
groups *pipelined* — group g+1's host-side input encoding overlaps group g's
device compute via JAX async dispatch.  Engine 'ref' delegates to the
pure-numpy oracle (``forest_infer_ref``); all engines produce bit-identical
survivors and therefore bit-identical votes.

Compiled batch functions are cached per (batch-bucket, engine, group,
plan_id) through the serving engine's ``CompileCache``, with batch shapes
bucketed up the same power-of-two ladder the server uses — a stream of
varying batch sizes costs a bounded number of jit compiles.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.energy import DEFAULT_HW, HardwareParams, forest_figures
from ..core.encode import encode_inputs
from ..kernels.banked import tcam_match_banked
from ..kernels.ops import default_interpret
from ..serve.batching import BucketPolicy
from ..serve.cache import CompileCache
from .compiler import CompiledForest, ForestResult, aggregate_votes, forest_infer_ref
from .plan import ForestPlan, PlanGroup, plan_forest

__all__ = ["ForestExecutor", "FOREST_ENGINES", "encode_group"]

FOREST_ENGINES = ("banked", "mxu", "ref")


def encode_group(
    forest: CompiledForest, group: PlanGroup, Xp: np.ndarray
) -> np.ndarray:
    """Per-bank encode + pad to the group's stacked shape: (G, B, W_pad).

    Each bank encodes the SAME raw inputs through its OWN thresholds — banks
    cannot share search words, which is why the stacked input carries a bank
    axis instead of broadcasting one batch.
    """
    b = Xp.shape[0]
    out = np.zeros((group.n_banks, b, group.width), dtype=np.uint8)
    for slot, bank_id in enumerate(group.bank_ids):
        bank = forest.banks[int(bank_id)]
        xpad = bank.layout.pad_inputs(encode_inputs(bank.lut, Xp))
        out[slot, :, : xpad.shape[1]] = xpad
    return out


class ForestExecutor:
    """Execute a ``CompiledForest`` on the banked kernels.

    >>> ex = ForestExecutor(forest, engine="banked")
    >>> res = ex.infer(X)
    >>> res.predictions, res.figures["aggregate"]["decs_pipe"]
    """

    def __init__(
        self,
        forest: CompiledForest,
        *,
        engine: str = "banked",
        hw: HardwareParams = DEFAULT_HW,
        interpret: Optional[bool] = None,
        block_b: int = 128,
        block_r: int = 128,
        min_bucket: int = 8,
        plan: Optional[ForestPlan] = None,
        kmax: Optional[list] = None,   # per-group (G, R, D) overrides
    ) -> None:
        if engine not in FOREST_ENGINES:
            raise ValueError(
                f"unknown forest engine {engine!r}; "
                f"expected one of {FOREST_ENGINES}"
            )
        self.forest = forest
        self.engine = engine
        self.hw = hw
        self.interpret = default_interpret() if interpret is None else interpret
        self.block_b = block_b
        self.block_r = block_r
        self.min_bucket = min_bucket
        self.plan = plan if plan is not None else plan_forest(forest)
        self._kmax = (
            [g.kmax0 for g in self.plan.groups] if kmax is None else list(kmax)
        )
        self.cache = CompileCache(self._build, self.plan.plan_id)

    # -- compile machinery --------------------------------------------------
    def _build(self, bucket: int, key: str):
        """One jit'd banked match per (batch-bucket, engine, group)."""
        engine, gi = key.rsplit(":g", 1)
        grp = self.plan.groups[int(gi)]
        km = jnp.asarray(self._kmax[int(gi)])
        run = functools.partial(
            tcam_match_banked, grp.cells, s=grp.s, kmax=km, engine=engine,
            block_b=self.block_b, block_r=self.block_r,
            interpret=self.interpret,
        )
        return jax.jit(lambda xpad: run(xpad))

    def _bucket_for(self, b: int) -> int:
        top = self.min_bucket
        while top < b:
            top *= 2
        policy = BucketPolicy(max_batch=top, min_bucket=self.min_bucket)
        return policy.bucket_for(b)

    def warmup(self, batch: int = 8) -> int:
        """Pre-compile every group for one batch bucket; returns #compiles."""
        if self.engine == "ref":
            return 0
        before = self.cache.misses
        bucket = self._bucket_for(batch)
        for gi, grp in enumerate(self.plan.groups):
            fn = self.cache.get(bucket, f"{self.engine}:g{gi}")
            x = jnp.zeros((grp.n_banks, bucket, grp.width), jnp.uint8)
            jax.block_until_ready(fn(x))
        return self.cache.misses - before

    # -- execution ----------------------------------------------------------
    def infer(
        self,
        X: np.ndarray,
        *,
        selective_precharge: bool = True,
        enabled: Optional[np.ndarray] = None,
    ) -> ForestResult:
        if self.engine == "ref":
            return forest_infer_ref(
                self.forest, X, hw=self.hw,
                selective_precharge=selective_precharge, enabled=enabled,
            )
        forest = self.forest
        Xp = forest.prepare_inputs(X, who="ForestExecutor.infer")
        b = Xp.shape[0]
        bucket = self._bucket_for(b)

        # pipelined dispatch: JAX queues group g's device compute
        # asynchronously, so encoding group g+1 on the host overlaps it
        pending = []
        for gi, grp in enumerate(self.plan.groups):
            xpad = encode_group(forest, grp, Xp)
            if bucket > b:
                xpad = np.pad(xpad, ((0, 0), (0, bucket - b), (0, 0)))
            fn = self.cache.get(bucket, f"{self.engine}:g{gi}")
            pending.append((grp, fn(jnp.asarray(xpad))))

        survivors = np.empty((forest.n_banks, b), np.int32)
        n_survivors = np.empty((forest.n_banks, b), np.int32)
        active = np.empty((forest.n_banks, b), np.int64)
        for grp, out in pending:
            survive, evals = (np.asarray(o) for o in out)
            for slot, bank_id in enumerate(grp.bank_ids):
                i = int(bank_id)
                rows_i = int(grp.rows[slot])
                d_i = int(grp.d_real[slot])
                sv = survive[slot, :b, :rows_i]
                ns = sv.sum(axis=1).astype(np.int32)
                first = np.argmax(sv, axis=1).astype(np.int32)
                survivors[i] = np.where(ns > 0, first, -1)
                n_survivors[i] = ns
                if selective_precharge:
                    # padding divisions trivially match: clamp each row's
                    # eval count back to the bank's real division count
                    ev = np.minimum(evals[slot, :b, :rows_i], d_i)
                    active[i] = ev.sum(axis=1).astype(np.int64)
                else:
                    active[i] = rows_i * d_i

        predictions, score = aggregate_votes(forest, survivors, enabled)
        en = (np.ones(forest.n_banks, bool) if enabled is None
              else np.asarray(enabled, bool))
        figures = forest_figures(
            forest.layouts, self.hw,
            mean_active_evals=[float(a.mean()) for a in active],
        )
        return ForestResult(
            predictions=predictions,
            score=score,
            survivors=survivors,
            n_survivors=n_survivors,
            active_evals=active,
            enabled=en,
            engine=self.engine,
            figures=figures,
        )

    __call__ = infer
