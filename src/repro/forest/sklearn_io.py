"""sklearn interop: lossless import of fitted sklearn trees/forests.

Converts ``sklearn.tree._tree.Tree`` flat arrays into the repo's
``DecisionTree`` (same split semantics: ``x[f] <= threshold`` goes left) and
extracts the per-leaf class-probability tables needed to reproduce
``RandomForestClassifier.predict`` *bit-exactly*:

* leaf probabilities replicate ``DecisionTreeClassifier.predict_proba``
  including its normalizer quirk (rows summing to zero divide by 1);
* probabilities are indexed by LUT row via ``tree_leaf_ids`` (both the rule
  table and the DFS leaf walk enumerate leaves left-to-right);
* sklearn casts inputs to float32 inside ``predict`` — the importer records
  that so the forest front door applies the same cast before encoding.

Everything here is numpy-only and degrades gracefully: when sklearn is not
installed, ``is_sklearn_forest`` simply returns False.
"""
from __future__ import annotations

import numpy as np

from ..core.cart import DecisionTree, tree_leaf_ids

__all__ = [
    "is_sklearn_forest", "from_sklearn_tree", "leaf_proba_rows",
]


def is_sklearn_forest(obj) -> bool:
    """Duck-typed check for a fitted sklearn forest ensemble
    (``RandomForestClassifier``-like: ``estimators_`` + ``classes_``)."""
    return hasattr(obj, "estimators_") and hasattr(obj, "classes_")


def from_sklearn_tree(estimator) -> DecisionTree:
    """Convert a fitted ``DecisionTreeClassifier`` to a ``DecisionTree``.

    sklearn leaves carry ``feature == TREE_UNDEFINED`` (-2) — mapped to the
    repo's -1 sentinel; split rule and child order are identical
    (``x[f] <= threshold`` -> left child).
    """
    t = estimator.tree_
    feature = np.asarray(t.feature, dtype=np.int32)
    feature = np.where(feature < 0, -1, feature).astype(np.int32)
    value = np.asarray(t.value, dtype=np.float64)[:, 0, :]
    return DecisionTree(
        feature=feature,
        threshold=np.asarray(t.threshold, dtype=np.float64),
        left=np.asarray(t.children_left, dtype=np.int32),
        right=np.asarray(t.children_right, dtype=np.int32),
        value=np.argmax(value, axis=1).astype(np.int32),
        n_features=int(t.n_features),
        n_classes=int(value.shape[1]),
    )


def leaf_proba_rows(estimator, tree: DecisionTree) -> np.ndarray:
    """(n_leaves, n_classes) float64 leaf probabilities in LUT-row order.

    Row ``r`` of the compiled LUT corresponds to leaf ``tree_leaf_ids[r]``;
    each row replicates ``DecisionTreeClassifier.predict_proba`` bit-for-bit:
    ``value[leaf] / sum`` with zero sums divided by 1 instead.
    """
    raw = np.asarray(estimator.tree_.value, dtype=np.float64)[:, 0, :]
    normalizer = raw.sum(axis=1)[:, np.newaxis]
    normalizer[normalizer == 0.0] = 1.0
    proba = raw / normalizer
    return np.ascontiguousarray(proba[tree_leaf_ids(tree)])
