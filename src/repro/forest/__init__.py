"""Tree-ensemble -> multi-bank TCAM: compiler, sharding plan, executors.

The paper's pipelined multi-array throughput story generalizes from one tree
on one chip to a forest sharded across TCAM banks:

  sklearn_io.py — lossless import of fitted sklearn trees/forests
  compiler.py   — compile_forest / ForestBank / CompiledForest + the
                  pure-numpy reference executor and vote aggregation
  plan.py       — ForestPlan: power-of-two shape bucketing, bank stacking
  executor.py   — ForestExecutor: batched/vmapped JAX execution, pipelined
                  across groups (imported lazily — needs jax)

``compile_forest`` + ``forest_infer_ref`` are numpy-only; accessing
``ForestExecutor`` (or anything from ``executor``) pulls in jax on demand.
"""
from .compiler import (
    VOTES,
    CompiledForest,
    ForestBank,
    ForestResult,
    aggregate_votes,
    compile_forest,
    forest_infer_ref,
    train_forest,
)
from .plan import ForestPlan, PlanGroup, plan_forest
from .sklearn_io import from_sklearn_tree, is_sklearn_forest, leaf_proba_rows

__all__ = [
    "VOTES", "CompiledForest", "ForestBank", "ForestResult",
    "aggregate_votes", "compile_forest", "forest_infer_ref", "train_forest",
    "ForestPlan", "PlanGroup", "plan_forest",
    "from_sklearn_tree", "is_sklearn_forest", "leaf_proba_rows",
    "ForestExecutor", "FOREST_ENGINES", "encode_group",
]

_LAZY = {"ForestExecutor", "FOREST_ENGINES", "encode_group"}


def __getattr__(name: str):
    if name in _LAZY:
        from . import executor

        return getattr(executor, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
