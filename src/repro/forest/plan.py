"""Sharding plan: stack same-shape banks into batched execution groups.

Each bank is an independent tiled TCAM, but banks whose padded shapes agree
can be evaluated by ONE batched kernel invocation over a leading bank axis
(``repro.kernels.banked``).  ``plan_forest`` buckets every bank's physical
(rows, divisions) up a power-of-two ladder — the same ``BucketPolicy``
machinery the serving engine uses for batch shapes — and stacks banks with
equal bucketed shape into a ``PlanGroup``:

* padding rows beyond a bank's physical array carry ``kmax = -1`` (always
  mismatch: they can neither survive nor disturb the vote);
* padding divisions are all-CELL_X (trivially match), and the executor
  corrects the activity counts with ``min(evals, d_real)`` per bank —
  safe because no row can die inside a fully-masked division.

The plan is content-addressed (``plan_id``) so compiled batch functions can
be cached per (plan, engine, batch-bucket), mirroring the serving engine's
compile-cache discipline.
"""
from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

from ..core.lut import CELL_X

__all__ = ["PlanGroup", "ForestPlan", "plan_forest"]


@dataclasses.dataclass
class PlanGroup:
    """Banks stacked to one padded shape, executable in one invocation."""

    bank_ids: np.ndarray   # (G,) int64 — indices into the forest's bank list
    s: int
    r_pad: int             # padded physical rows per bank
    d_pad: int             # padded column divisions per bank
    cells: np.ndarray      # (G, r_pad, d_pad*s) int8 stacked cell grids
    kmax0: np.ndarray      # (G, r_pad, d_pad) int32 ideal kmax (-1 pad rows)
    rows: np.ndarray       # (G,) int64 — real physical rows per bank
    d_real: np.ndarray     # (G,) int64 — real divisions per bank

    @property
    def n_banks(self) -> int:
        return len(self.bank_ids)

    @property
    def width(self) -> int:
        return self.d_pad * self.s


@dataclasses.dataclass
class ForestPlan:
    groups: list[PlanGroup]
    n_banks: int
    plan_id: str

    @property
    def n_groups(self) -> int:
        return len(self.groups)


def _pow2_bucket(n: int, min_bucket: int, max_cap: int):
    """BucketPolicy ladder covering n: min_bucket, 2·min_bucket, ... >= n."""
    # lazy import: keeps repro.forest importable without pulling in the
    # (jax-importing) serve engine package
    from ..serve.batching import BucketPolicy

    cap = max(min_bucket, max_cap)
    top = min_bucket
    while top < cap:
        top *= 2
    return BucketPolicy(max_batch=top, min_bucket=min_bucket).bucket_for(n)


def plan_forest(layouts_or_forest) -> ForestPlan:
    """Build the sharded execution plan for a forest (or a bare list of
    ``TCAMLayout``-likes, e.g. the serving engine's per-bank faulted grids).
    """
    layouts = getattr(layouts_or_forest, "layouts", layouts_or_forest)
    layouts = list(layouts)
    if not layouts:
        raise ValueError("plan_forest needs at least one bank layout")
    s = int(layouts[0].s)
    if any(int(l.s) != s for l in layouts):
        raise ValueError("all banks must share the same tile size S")

    rows = np.array([l.cells.shape[0] for l in layouts], np.int64)
    divs = np.array([int(l.n_cwd) for l in layouts], np.int64)
    max_rows, max_divs = int(rows.max()), int(divs.max())

    keys: dict[tuple[int, int], list[int]] = {}
    for i in range(len(layouts)):
        r_pad = _pow2_bucket(int(rows[i]), s, max_rows)
        d_pad = _pow2_bucket(int(divs[i]), 1, max_divs)
        keys.setdefault((r_pad, d_pad), []).append(i)

    digest = hashlib.sha1()
    groups = []
    for (r_pad, d_pad), ids in sorted(keys.items()):
        g = len(ids)
        w_pad = d_pad * s
        cells = np.full((g, r_pad, w_pad), CELL_X, dtype=np.int8)
        kmax0 = np.zeros((g, r_pad, d_pad), dtype=np.int32)
        for slot, i in enumerate(ids):
            lay = layouts[i]
            r, w = lay.cells.shape
            cells[slot, :r, :w] = lay.cells
            kmax0[slot, r:, :] = -1  # stacking pad rows: always mismatch
        groups.append(PlanGroup(
            bank_ids=np.asarray(ids, np.int64),
            s=s, r_pad=r_pad, d_pad=d_pad,
            cells=cells, kmax0=kmax0,
            rows=rows[ids], d_real=divs[ids],
        ))
        digest.update(cells.tobytes())
        digest.update(np.asarray(ids, np.int64).tobytes())
    for lay in layouts:
        digest.update(lay.classes.tobytes())
    return ForestPlan(
        groups=groups,
        n_banks=len(layouts),
        plan_id=digest.hexdigest()[:12],
    )
