"""LM substrate: model definitions for the assigned architectures.

Pure-functional JAX (no framework): params are pytrees of jnp arrays with a
parallel pytree of logical-axis names (see ``repro.sharding``).  Layer stacks
run as ``lax.scan`` over repeating *super-blocks* so heterogeneous
architectures (jamba's 1:7 mamba/attention interleave with alternating MoE)
compile to small HLO.
"""
from .config import ARCH_FAMILIES, ModelConfig
from .lm import (
    decode_step,
    forward,
    init_cache,
    loss_fn,
    prefill,
)
from .params import init_params, param_count, param_logical_axes

__all__ = [
    "ARCH_FAMILIES", "ModelConfig",
    "decode_step", "forward", "init_cache", "loss_fn", "prefill",
    "init_params", "param_count", "param_logical_axes",
]
