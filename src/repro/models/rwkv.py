"""RWKV6 ("Finch") mixer: linear attention with data-dependent diagonal
decay, plus RWKV channel-mix.

Training/prefill uses the *chunked* WKV formulation (matmul-rich, TPU
friendly): within a chunk of C tokens, with per-step decay vectors
``w_t ∈ (0,1)^hd`` and cumulative log-decay ``L_t = Σ_{τ<=t} log w_τ``,

  y_t = (r_t ⊙ e^{L_{t-1}}) · S_in                       (inter-chunk)
      + Σ_{j<t} [(r_t ⊙ e^{L_{t-1}}) · (k_j ⊙ e^{-L_j})] v_j   (intra)
      + (r_t ⊙ u ⊙ k_t) · v_t                            (bonus diagonal)
  S_out = diag(e^{L_C}) S_in + Σ_j (k_j ⊙ e^{L_C - L_j}) v_jᵀ

The e^{-L_j} factor is clipped at e^{30} — pairs that would overflow have
decayed below f32 noise anyway (contribution ~e^{-30}).

Decode is the exact recurrence S_t = diag(w_t) S_{t-1} + k_t v_tᵀ.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..sharding import shard
from .config import ModelConfig
from .layers import rms_norm

__all__ = ["rwkv_mixer", "rwkv_decode", "rwkv_channel_mix",
           "init_rwkv_state", "CHUNK"]

# CHUNK x max|log w| must stay inside f32 exponent range: with the decay
# exponent clamped at 1.0 (per-step log-decay >= -e = -2.72), |L| <= 43.5
# over a 16-token chunk — e^{±L} is exactly representable, so the chunked
# factorization r·e^{L_{t-1}} @ (k·e^{-L_j})ᵀ is exact to fp rounding
# (validated against the token recurrence in tests/test_models.py).
CHUNK = 16
CLIP = 44.0


def _proj(x, w, dt):
    return jnp.einsum("bsd,de->bse", x, w.astype(dt))


def _mix_heads(x: jax.Array, x_prev: jax.Array, p: dict, cfg: ModelConfig):
    """Token-shift mixing + projections -> per-head r, k, v, g, log-decay."""
    dt = x.dtype
    b, s, d = x.shape
    h, hd = cfg.rwkv_heads, cfg.rwkv_head_dim
    shifted = jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)
    mu = p["mu"].astype(dt)                                  # (5, D)
    xs = [x + mu[i] * (shifted - x) for i in range(5)]       # r k v w g
    hs = lambda t: shard(t.reshape(b, s, h, hd),
                         "act_batch", "act_seq", "act_heads", None)
    r = hs(_proj(xs[0], p["wr"], dt))
    k = hs(_proj(xs[1], p["wk"], dt))
    v = hs(_proj(xs[2], p["wv"], dt))
    # data-dependent decay (low-rank): w = exp(-exp(base + tanh(x A) B))
    wl = jnp.einsum(
        "bsd,dr->bsr", xs[3].astype(jnp.float32),
        p["w_lora_a"].astype(jnp.float32),
    )
    wl = jnp.einsum("bsr,rd->bsd", jnp.tanh(wl), p["w_lora_b"].astype(jnp.float32))
    logw = -jnp.exp(
        jnp.clip(p["w_base"].astype(jnp.float32) + wl, -8.0, 1.0)
    ).reshape(b, s, h, hd)                                   # log w_t < 0
    g = jax.nn.silu(_proj(xs[4], p["wg"], dt))               # (B,S,D)
    return r, k, v, logw, g


def _wkv_chunk(s_in, r, k, v, logw, u):
    """One chunk; r/k/v (B,H,C,hd) f32, logw (B,H,C,hd), u (H,hd),
    s_in (B,H,hd,hd).  Returns (s_out, y (B,H,C,hd))."""
    c = r.shape[2]
    lcum = jnp.cumsum(logw, axis=2)                          # L_t (incl. t)
    lprev = lcum - logw                                      # L_{t-1}
    r_t = r * jnp.exp(lprev)
    k_t = k * jnp.exp(jnp.minimum(-lcum, CLIP))
    a = jnp.einsum("bhtd,bhjd->bhtj", r_t, k_t)              # (B,H,C,C)
    mask = jnp.tril(jnp.ones((c, c), bool), k=-1)
    a = jnp.where(mask[None, None], a, 0.0)
    # bonus diagonal: (r_t ⊙ u ⊙ k_t)·v_t
    diag = jnp.einsum("bhtd,bhtd->bht", r * u[None, :, None, :], k)
    y = (
        jnp.einsum("bhtd,bhde->bhte", r_t, s_in)
        + jnp.einsum("bhtj,bhje->bhte", a, v)
        + diag[..., None] * v
    )
    ltot = lcum[:, :, -1:, :]                                # L_C
    k_s = k * jnp.exp(ltot - lcum)
    s_out = jnp.exp(ltot.squeeze(2))[..., None] * s_in + jnp.einsum(
        "bhjd,bhje->bhde", k_s, v
    )
    return s_out, y


def rwkv_mixer(
    x: jax.Array,              # (B, S, D)
    p: dict,
    cfg: ModelConfig,
    state: tuple | None = None,   # (x_prev (B,D), S (B,H,hd,hd))
    return_state: bool = False,
):
    b, s, d = x.shape
    h, hd = cfg.rwkv_heads, cfg.rwkv_head_dim
    x_prev = state[0] if state is not None else jnp.zeros((b, d), x.dtype)
    s_in = (state[1] if state is not None
            else jnp.zeros((b, h, hd, hd), jnp.float32))
    r, k, v, logw, g = _mix_heads(x, x_prev, p, cfg)

    chunk = min(CHUNK, s)
    while s % chunk:            # largest divisor of s that is <= CHUNK
        chunk -= 1
    nc = s // chunk
    u = p["u"].astype(jnp.float32)
    rf, kf, vf, wf = (t.astype(jnp.float32).transpose(0, 2, 1, 3)
                      for t in (r, k, v, logw))              # (B,H,S,hd)

    @jax.checkpoint  # recompute per chunk in backward
    def step(s_st, idx):
        sl = lambda t: jax.lax.dynamic_slice_in_dim(t, idx * chunk, chunk, 2)
        s_st, y = _wkv_chunk(s_st, sl(rf), sl(kf), sl(vf), sl(wf), u)
        return s_st, y

    s_out, ys = jax.lax.scan(step, s_in, jnp.arange(nc))     # ys (nc,B,H,C,hd)
    y = ys.transpose(1, 0, 3, 2, 4).reshape(b, s, h, hd)     # (B,S,H,hd)
    # per-head group norm, then gate and output projection
    y = rms_norm(y, p["ln_x"].reshape(h, hd), cfg.norm_eps).reshape(b, s, d)
    y = (y * g).astype(x.dtype)
    out = jnp.einsum("bsd,de->bse", y, p["wo"].astype(x.dtype))
    out = shard(out, "act_batch", "act_seq", "act_embed")
    if return_state:
        return out, (x[:, -1, :], s_out)
    return out


def rwkv_decode(x, p, cfg, state):
    return rwkv_mixer(x, p, cfg, state=state, return_state=True)


def rwkv_channel_mix(
    x: jax.Array, p: dict, cfg: ModelConfig,
    state: jax.Array | None = None,    # x_prev (B, D)
    return_state: bool = False,
):
    b, s, d = x.shape
    dt = x.dtype
    x_prev = state if state is not None else jnp.zeros((b, d), dt)
    shifted = jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)
    mu = p["cm_mu"].astype(dt)
    xk = x + mu[0] * (shifted - x)
    xr = x + mu[1] * (shifted - x)
    kk = jnp.einsum("bsd,df->bsf", xk, p["cm_k"].astype(dt))
    kk = shard(kk, "act_batch", "act_seq", "act_mlp")
    kk = jnp.square(jax.nn.relu(kk))
    vv = jnp.einsum("bsf,fd->bsd", kk, p["cm_v"].astype(dt))
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["cm_r"].astype(dt)))
    out = shard(rr * vv, "act_batch", "act_seq", "act_embed")
    if return_state:
        return out, x[:, -1, :]
    return out


def init_rwkv_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    h, hd, d = cfg.rwkv_heads, cfg.rwkv_head_dim, cfg.d_model
    return (
        jnp.zeros((batch, d), dtype),                        # attn x_prev
        jnp.zeros((batch, h, hd, hd), jnp.float32),          # wkv state
        jnp.zeros((batch, d), dtype),                        # cmix x_prev
    )
