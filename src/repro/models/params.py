"""Parameter pytree construction + logical sharding axes.

Every leaf is described once in a *leaf spec* ``(shape, logical_axes, init)``
so the init pytree and the logical-axis pytree can never drift apart.
Stacked layer leaves get a leading ``total_occurrences`` dim (logical name
"layers", always replicated) and are consumed by the super-block scan.
"""
from __future__ import annotations

import math
from typing import Callable, Union

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import dense_init

__all__ = ["init_params", "param_logical_axes", "param_count"]

Init = Union[str, Callable]


def _leaf(key, shape, init: Init, dtype=jnp.float32):
    if callable(init):
        return init(key, shape).astype(dtype)
    if init == "zeros":
        return jnp.zeros(shape, dtype)
    if init == "ones":
        return jnp.ones(shape, dtype)
    if init.startswith("dense"):
        ax = int(init[5:] or 0)
        return dense_init(key, shape, in_axis=ax, dtype=dtype)
    raise ValueError(init)


# ---------------------------------------------------------------------------
# leaf specs per layer kind: name -> (shape, logical axes, init)
# ---------------------------------------------------------------------------
def _attn_specs(cfg: ModelConfig) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    out = {
        "norm1": ((d,), (None,), "zeros"),
        "wq": ((d, h * hd), ("embed", "qkv"), "dense0"),
        "wk": ((d, kv * hd), ("embed", "qkv"), "dense0"),
        "wv": ((d, kv * hd), ("embed", "qkv"), "dense0"),
        "wo": ((h * hd, d), ("qkv", "embed"), "dense0"),
    }
    if cfg.qk_norm:
        out["q_norm"] = ((hd,), (None,), "zeros")
        out["k_norm"] = ((hd,), (None,), "zeros")
    return out


def _mlp_specs(cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "norm2": ((d,), (None,), "zeros"),
        "w_gate": ((d, f), ("embed", "mlp"), "dense0"),
        "w_up": ((d, f), ("embed", "mlp"), "dense0"),
        "w_down": ((f, d), ("mlp", "embed"), "dense0"),
    }


def _moe_specs(cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.expert_ff, cfg.n_experts
    return {
        "norm2": ((d,), (None,), "zeros"),
        "w_router": ((d, e), ("embed", "experts"), "dense0"),
        "w_gate": ((e, d, f), ("experts", "embed", None), "dense1"),
        "w_up": ((e, d, f), ("experts", "embed", None), "dense1"),
        "w_down": ((e, f, d), ("experts", None, "embed"), "dense1"),
    }


def _mamba_specs(cfg: ModelConfig) -> dict:
    d, di, n, k, r = (cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_conv,
                      cfg.dt_rank)

    def a_log_init(key, shape):
        a = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None], (di, 1))
        return jnp.log(a)

    def dt_b_init(key, shape):
        # inverse-softplus of dt ~ U[1e-3, 1e-1]
        dt = jnp.exp(
            jax.random.uniform(key, shape) * (math.log(0.1) - math.log(1e-3))
            + math.log(1e-3)
        )
        return dt + jnp.log(-jnp.expm1(-dt))

    return {
        "norm1": ((d,), (None,), "zeros"),
        "in_proj": ((d, 2 * di), ("embed", "dinner"), "dense0"),
        "conv_w": ((di, k), ("dinner", None), "dense1"),
        "conv_b": ((di,), ("dinner",), "zeros"),
        "x_proj": ((di, r + 2 * n), ("dinner", None), "dense0"),
        "dt_w": ((r, di), (None, "dinner"), "dense0"),
        "dt_b": ((di,), ("dinner",), dt_b_init),
        "A_log": ((di, n), ("dinner", None), a_log_init),
        "Dskip": ((di,), ("dinner",), "ones"),
        "out_proj": ((di, d), ("dinner", "embed"), "dense0"),
    }


def _rwkv_specs(cfg: ModelConfig) -> dict:
    d, h, hd = cfg.d_model, cfg.rwkv_heads, cfg.rwkv_head_dim
    lora = 64

    def w_base_init(key, shape):
        # per-channel decay spread: exp(-exp(w)) from ~0.37 to ~0.999
        lin = jnp.linspace(-6.0, 1.0, d)
        return lin

    def u_init(key, shape):
        return 0.5 * jax.random.normal(key, shape)

    return {
        "norm1": ((d,), (None,), "zeros"),
        "mu": ((5, d), (None, None), lambda k_, s_: 0.5 * jnp.ones(s_)),
        "w_base": ((d,), (None,), w_base_init),
        "w_lora_a": ((d, lora), ("embed", None), "dense0"),
        "w_lora_b": ((lora, d), (None, "embed"), "zeros"),
        "wr": ((d, d), ("embed", "qkv"), "dense0"),
        "wk": ((d, d), ("embed", "qkv"), "dense0"),
        "wv": ((d, d), ("embed", "qkv"), "dense0"),
        "wg": ((d, d), ("embed", "qkv"), "dense0"),
        "u": ((h, hd), (None, None), u_init),
        "ln_x": ((d,), (None,), "zeros"),
        "wo": ((d, d), ("qkv", "embed"), "dense0"),
    }


def _cmix_specs(cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "norm2": ((d,), (None,), "zeros"),
        "cm_mu": ((2, d), (None, None), lambda k_, s_: 0.5 * jnp.ones(s_)),
        "cm_k": ((d, f), ("embed", "mlp"), "dense0"),
        "cm_v": ((f, d), ("mlp", "embed"), "dense0"),
        "cm_r": ((d, d), ("embed", "qkv"), "dense0"),
    }


def _cross_specs(cfg: ModelConfig) -> dict:
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    return {
        "norm_x": ((d,), (None,), "zeros"),
        "xwq": ((d, h * hd), ("embed", "qkv"), "dense0"),
        "xwk": ((d, h * hd), ("embed", "qkv"), "dense0"),
        "xwv": ((d, h * hd), ("embed", "qkv"), "dense0"),
        "xwo": ((h * hd, d), ("qkv", "embed"), "dense0"),
    }


_MIXERS = {"attn": _attn_specs, "swa": _attn_specs, "mamba": _mamba_specs,
           "rwkv": _rwkv_specs}
_FFNS = {"mlp": _mlp_specs, "moe": _moe_specs, "cmix": _cmix_specs}


def kind_specs(cfg: ModelConfig, kind: str, with_cross: bool = False) -> dict:
    mixer, ffn = kind.split("+")
    specs = {}
    specs.update(_MIXERS[mixer](cfg))
    if with_cross:
        specs.update(_cross_specs(cfg))
    specs.update(_FFNS[ffn](cfg))
    return specs


def _build(cfg: ModelConfig, key, *, axes_only: bool) -> dict:
    counter = [0]

    def nxt():
        counter[0] += 1
        return jax.random.fold_in(key, counter[0]) if key is not None else None

    def leaf(shape, axes, init, stack: int = 0):
        full_axes = (("layers",) + tuple(axes)) if stack else tuple(axes)
        if axes_only:
            return full_axes
        if stack:
            ks = [nxt() for _ in range(stack)]
            return jnp.stack([_leaf(k_, shape, init) for k_ in ks])
        return _leaf(nxt(), shape, init)

    d, v = cfg.d_model, cfg.vocab_size
    out: dict = {
        "embed": leaf((v, d), ("vocab", "embed"),
                      lambda k_, s_: 0.02 * jax.random.normal(k_, s_)),
        "final_norm": leaf((d,), (None,), "zeros"),
    }
    if not cfg.tie_embeddings:
        out["lm_head"] = leaf((d, v), ("embed", "vocab"), "dense0")

    blocks = {}
    for kind in cfg.kinds:
        occ = len(cfg.kind_positions(kind)) * cfg.n_repeat
        specs = kind_specs(cfg, kind, with_cross=cfg.is_encdec)
        blocks[kind] = {
            name: leaf(shape, axes, init, stack=occ)
            for name, (shape, axes, init) in specs.items()
        }
    out["blocks"] = blocks

    if cfg.is_encdec:
        enc_blocks = {
            name: leaf(shape, axes, init, stack=cfg.encoder_layers)
            for name, (shape, axes, init) in kind_specs(cfg, "attn+mlp").items()
        }
        out["encoder"] = {
            "blocks": {"attn+mlp": enc_blocks},
            "final_norm": leaf((d,), (None,), "zeros"),
            "pos_emb": leaf((cfg.encoder_seq, d), (None, None),
                            lambda k_, s_: 0.02 * jax.random.normal(k_, s_)),
        }
        out["dec_pos_emb"] = leaf(
            (32768, d), (None, None),
            lambda k_, s_: 0.02 * jax.random.normal(k_, s_))
    return out


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    return _build(cfg, key, axes_only=False)


def param_logical_axes(cfg: ModelConfig) -> dict:
    return _build(cfg, None, axes_only=True)


def param_count(params) -> int:
    return int(sum(np.prod(x.shape) for x in jax.tree.leaves(params)))
