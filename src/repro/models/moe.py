"""Mixture-of-Experts FFN: top-k routing with capacity-bounded scatter/gather
dispatch (no giant one-hot dispatch einsums), expert-parallel over the
"model" mesh axis.

Dispatch: tokens are ranked within their chosen expert via a sort-free
cumulative-position trick; tokens beyond an expert's capacity
``C = ceil(cf * T * k / E)`` are dropped (standard GShard/Switch semantics).
The (E, C, D) expert buffer is the only materialized dispatch structure:
bytes = E*C*D ~= cf * k * tokens * d_model, independent of E.

``router="tcam_dt"`` (beyond-paper, DESIGN.md §4): routing decisions come
from a decision tree compiled to a ternary LUT by the paper's DT-HW compiler
and evaluated with the TCAM bitplane match — see ``tcam_router.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..sharding import shard
from .config import ModelConfig

__all__ = ["moe_ffn", "capacity"]


def capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(cfg.capacity_factor * n_tokens * cfg.experts_per_token
            / cfg.n_experts + 0.999)
    return max(8, -(-c // 8) * 8)  # round up to multiple of 8


def _positions_in_expert(flat_e: jax.Array, n_experts: int) -> jax.Array:
    """Rank of each dispatch within its expert (stable, order-preserving).

    Equivalent to grouping by expert and numbering arrivals; computed with a
    sort + inverse permutation (O(n log n), no (T, E) one-hot)."""
    n = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.zeros((n_experts,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts
    pos_sorted = jnp.arange(n, dtype=jnp.int32) - starts[sorted_e]
    return jnp.zeros((n,), jnp.int32).at[order].set(pos_sorted)


def moe_ffn(
    x: jax.Array,                 # (B, S, D)
    p: dict,                      # w_router (D,E), w_gate/w_up (E,D,F), w_down (E,F,D)
    cfg: ModelConfig,
    *,
    router_bits: dict | None = None,   # tcam_dt router arrays (see tcam_router)
) -> jax.Array:
    b, s, d = x.shape
    t = b * s
    g = cfg.moe_groups if (b * s) % cfg.moe_groups == 0 else 1  # decode: t=B
    if g > 1:
        # GShard-style token groups: route/dispatch/compute one group at a
        # time (checkpointed scan) — dispatch transients scale 1/g.
        xg = x.reshape(g, t // g, 1, d)

        @jax.checkpoint
        def one(_, xc):
            return None, _moe_group(xc, p, cfg, router_bits)

        _, yg = jax.lax.scan(one, None, xg)
        return yg.reshape(b, s, d)
    return _moe_group(x.reshape(t, 1, d), p, cfg, router_bits).reshape(b, s, d)


def _moe_group(
    x: jax.Array,                 # (T, 1, D) — one token group
    p: dict,
    cfg: ModelConfig,
    router_bits: dict | None = None,
) -> jax.Array:
    t, _, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    f = cfg.expert_ff
    dt = x.dtype
    xt = x.reshape(t, d)

    if cfg.router == "tcam_dt":
        from .tcam_router import route_tcam
        assert router_bits is not None, "tcam_dt router needs compiled bits"
        top_i = route_tcam(xt, router_bits)[:, None]        # (T, 1) top-1
        top_w = jnp.ones((t, 1), jnp.float32)
        k = 1
    else:
        logits = jnp.einsum(
            "td,de->te", xt.astype(jnp.float32),
            p["w_router"].astype(jnp.float32),
        )
        gates = jax.nn.softmax(logits, axis=-1)
        top_w, top_i = jax.lax.top_k(gates, k)              # (T, k)
        top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    c = capacity(cfg, t)
    flat_e = top_i.reshape(-1).astype(jnp.int32)            # (T*k,)
    pos = _positions_in_expert(flat_e, e)
    keep = pos < c
    slot = jnp.where(keep, flat_e * c + pos, e * c)         # overflow -> slot E*C

    x_rep = jnp.repeat(xt, k, axis=0)                       # (T*k, D)
    # Scatter with the operand sharded on D (model axis): each shard scatters
    # its D-slice locally (indices replicated, no giant replicated buffer).
    # The reshard to expert-sharded right after IS the EP dispatch
    # all-to-all of real expert-parallel systems.
    src = shard(jnp.where(keep[:, None], x_rep, 0), None, "act_mlp")
    buf = shard(jnp.zeros((e * c + 1, d), dt), None, "act_mlp")
    buf = buf.at[slot].set(src)
    buf = shard(buf, None, "act_mlp")
    h = buf[: e * c].reshape(e, c, d)
    h = shard(h, "act_experts", None, None)                 # <- EP all-to-all

    g = jnp.einsum("ecd,edf->ecf", h, p["w_gate"].astype(dt))
    u = jnp.einsum("ecd,edf->ecf", h, p["w_up"].astype(dt))
    act = jax.nn.silu(g) if cfg.mlp_act == "silu" else jax.nn.gelu(g)
    out = jnp.einsum("ecf,efd->ecd", act * u, p["w_down"].astype(dt))
    out = shard(out, "act_experts", None, None)

    out_flat = jnp.concatenate(
        [out.reshape(e * c, d), jnp.zeros((1, d), dt)], axis=0
    )
    out_flat = shard(out_flat, None, "act_mlp")             # <- return A2A
    y_disp = out_flat[slot] * keep[:, None].astype(dt)      # (T*k, D)
    y = (y_disp.reshape(t, k, d)
         * top_w.reshape(t, k, 1).astype(dt)).sum(axis=1)
    return y.reshape(t, 1, d)
