"""Common layers: norms, RoPE, dense MLPs, initializers.

Compute dtype is bf16 (params f32, cast at use); norms and softmax statistics
run in f32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..sharding import shard

__all__ = [
    "rms_norm", "nonparam_norm", "rope", "rope_table", "mlp",
    "dense_init", "COMPUTE_DTYPE",
]

COMPUTE_DTYPE = jnp.bfloat16


def dense_init(key: jax.Array, shape, in_axis: int = 0,
               dtype=jnp.float32) -> jax.Array:
    """Truncated-normal fan-in init (std = 1/sqrt(fan_in))."""
    fan_in = shape[in_axis]
    std = fan_in ** -0.5
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def nonparam_norm(x: jax.Array, eps: float) -> jax.Array:
    """OLMo's non-parametric LayerNorm (no scale/bias)."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def rope_table(positions: jax.Array, head_dim: int,
               theta: float) -> tuple[jax.Array, jax.Array]:
    """(sin, cos) tables, f32, shape positions.shape + (head_dim//2,)."""
    half = head_dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.sin(ang), jnp.cos(ang)


def rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """Apply rotary embedding; x: (..., seq, heads, head_dim); sin/cos:
    (..., seq, head_dim//2) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    s = sin[..., None, :].astype(jnp.float32)
    c = cos[..., None, :].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * c - x2f * s, x2f * c + x1f * s], axis=-1)
    return out.astype(x.dtype)


def mlp(x: jax.Array, p: dict, act: str) -> jax.Array:
    """Gated MLP (SwiGLU / GeGLU): (w_gate, w_up) -> act(g) * u -> w_down."""
    dt = x.dtype
    g = jnp.einsum("...d,df->...f", x, p["w_gate"].astype(dt))
    u = jnp.einsum("...d,df->...f", x, p["w_up"].astype(dt))
    g = shard(g, "act_batch", "act_seq", "act_mlp")
    u = shard(u, "act_batch", "act_seq", "act_mlp")
    h = (jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)) * u
    out = jnp.einsum("...f,fd->...d", h, p["w_down"].astype(dt))
    return shard(out, "act_batch", "act_seq", "act_embed")
