"""Model configuration shared by every assigned architecture.

A config fully determines the parameter pytree, the super-block layer
pattern, and the train/prefill/decode computations.  The ten assigned
architectures instantiate these in ``repro.configs``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ModelConfig", "ARCH_FAMILIES", "LayerKind"]

ARCH_FAMILIES = ("dense", "moe", "ssm", "hybrid", "vlm", "audio")

# a layer kind is "<mixer>+<ffn>": mixer in {attn, swa, mamba, rwkv},
# ffn in {mlp, moe}
LayerKind = str


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # one of ARCH_FAMILIES
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- layer pattern: `pattern` repeats `n_layers // len(pattern)` times ---
    pattern: Tuple[LayerKind, ...] = ("attn+mlp",)

    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0              # per-expert hidden dim (0 -> d_ff)
    capacity_factor: float = 1.25
    moe_groups: int = 1            # GShard-style token groups: dispatch
                                   # transients scale 1/groups (checkpointed)
    router: str = "softmax"        # "softmax" | "tcam_dt" (beyond-paper)

    # --- attention ---
    rope_theta: float = 10_000.0
    sliding_window: int = 0        # >0 => SWA for 'swa' mixer layers
    qk_norm: bool = False          # qwen3-style per-head RMSNorm on q/k

    # --- MLP ---
    mlp_act: str = "silu"          # "silu" (swiglu) | "gelu" (geglu)

    # --- SSM (mamba) ---
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0           # 0 -> ceil(d_model / 16)

    # --- rwkv6 ---
    rwkv_head_dim: int = 64

    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0        # >0 => enc-dec; n_layers = decoder layers
    encoder_seq: int = 1500        # stub frontend frames after conv (audio)

    # --- multimodal stub frontend (paligemma) ---
    frontend_tokens: int = 0       # patch embeddings prepended to text

    # --- misc ---
    norm_eps: float = 1e-6
    norm_type: str = "rms"         # "rms" | "nonparam" (olmo)
    tie_embeddings: bool = True
    emb_scale: bool = False        # gemma multiplies embeddings by sqrt(d)

    def __post_init__(self):
        assert self.family in ARCH_FAMILIES, self.family
        assert self.n_layers % len(self.pattern) == 0, (
            self.name, self.n_layers, self.pattern)

    # ---- derived ----
    @property
    def n_repeat(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or -(-self.d_model // 16)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    @property
    def expert_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def kinds(self) -> Tuple[LayerKind, ...]:
        """Distinct layer kinds, stable order of first occurrence."""
        seen: list = []
        for k in self.pattern:
            if k not in seen:
                seen.append(k)
        return tuple(seen)

    def kind_positions(self, kind: LayerKind) -> Tuple[int, ...]:
        return tuple(i for i, k in enumerate(self.pattern) if k == kind)

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks), for reporting
        and the 6·N·D model-FLOPs roofline term."""
        d, v = self.d_model, self.vocab_size
        total = v * d  # embed (tied head)
        if not self.tie_embeddings:
            total += v * d
        qkv = d * (self.n_heads + 2 * self.n_kv_heads) * self.head_dim
        attn = qkv + self.n_heads * self.head_dim * d
        mlp = 3 * d * self.d_ff if self.mlp_act in ("silu", "gelu") else 2 * d * self.d_ff
        moe = self.n_experts * 3 * d * self.expert_ff + d * self.n_experts
        dtr = self.dt_rank
        mamba = (
            2 * d * self.d_inner                 # in_proj (x, z)
            + self.ssm_conv * self.d_inner       # conv
            + self.d_inner * (dtr + 2 * self.ssm_state)  # x -> dt, B, C
            + dtr * self.d_inner                 # dt_proj
            + self.d_inner * self.ssm_state      # A
            + self.d_inner                       # D
            + self.d_inner * d                   # out_proj
        )
        rwkv = (
            5 * d * d                            # r, k, v, gate, output
            + 2 * d * 64                         # decay LoRA
            + 2 * d                              # decay base, bonus u
        )
        cmix = 2 * d * self.d_ff + d * d         # channel-mix k, v, r
        per_kind = {"attn": attn, "swa": attn, "mamba": mamba, "rwkv": rwkv}
        per_ffn = {"mlp": mlp, "moe": moe, "cmix": cmix}
        for kind in self.pattern:
            mixer, ffn = kind.split("+")
            total += self.n_repeat * per_kind[mixer]
            total += self.n_repeat * per_ffn[ffn]
        if self.is_encdec:
            # encoder self-attn + mlp + decoder cross-attn
            total += self.encoder_layers * (attn + 2 * d * self.d_ff)
            total += self.n_layers * attn        # cross-attention
        return int(total)

    def n_active_params(self) -> int:
        """Active params per token (MoE: only routed experts) — the 6·N_active·D
        roofline term."""
        if not self.n_experts:
            return self.n_params()
        d = self.d_model
        moe_all = self.n_experts * 3 * d * self.expert_ff
        moe_act = self.experts_per_token * 3 * d * self.expert_ff
        n_moe_layers = sum(1 for k in self.pattern if k.endswith("+moe"))
        n_moe_layers *= self.n_repeat
        return int(self.n_params() - n_moe_layers * (moe_all - moe_act))
