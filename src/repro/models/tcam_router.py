"""Beyond-paper integration: a decision-tree MoE router compiled to a TCAM
LUT with the paper's DT-HW compiler and evaluated in-graph with the bitplane
match (DESIGN.md §4).

Pipeline:
  1. Train a CART tree mapping (a projection of) hidden states -> expert id
     (e.g. distilling a trained softmax router, or from k-means clusters).
  2. ``compile_router`` runs the paper's parse/reduce/encode pipeline and
     lowers the LUT to flat JAX arrays:
       bit_feat / bit_thr / bit_const — input encoding is pure comparisons
         (bit i of feature f's code = x[f] > th_{T-1-i}; trailing bit = 1),
       is0 / is1 — bitplanes of the encoded LUT rows,
       classes — expert id per row.
  3. ``route_tcam`` evaluates the match in-graph: one (T, W) x (W, R) matmul
     pair — exactly the paper's massively-parallel search, as the MoE router.

The TCAM router is top-1 (a DT predicts one class).  It is a selectable
``router="tcam_dt"`` config option; the dry-run cells use the standard
softmax router.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from ..core.cart import DecisionTree
from ..core.encode import encode_table, feature_thresholds
from ..core.lut import bitplanes
from ..core.reduce import reduce_tree

__all__ = ["compile_router", "route_tcam"]


def compile_router(tree: DecisionTree) -> dict:
    """Compile a CART tree into flat arrays for in-graph TCAM routing."""
    table = reduce_tree(tree)
    lut = encode_table(table)
    ths = feature_thresholds(table)

    bit_feat, bit_thr, bit_const = [], [], []
    for f_idx, th in enumerate(ths):
        t_i = th.size
        # feature code has t_i + 1 bits; bit i (left->right) compares against
        # th[t_i - 1 - i]; the last bit is constant 1.
        for i in range(t_i):
            bit_feat.append(f_idx)
            bit_thr.append(float(th[t_i - 1 - i]))
            bit_const.append(False)
        bit_feat.append(0)
        bit_thr.append(0.0)
        bit_const.append(True)
    is0, is1 = bitplanes(lut.cells)
    return {
        "bit_feat": jnp.asarray(np.array(bit_feat, np.int32)),
        "bit_thr": jnp.asarray(np.array(bit_thr, np.float32)),
        "bit_const": jnp.asarray(np.array(bit_const)),
        "is0": jnp.asarray(is0.astype(np.float32)),
        "is1": jnp.asarray(is1.astype(np.float32)),
        "classes": jnp.asarray(lut.classes.astype(np.int32)),
    }


def route_tcam(x: jax.Array, bits: dict) -> jax.Array:
    """(T, D) hidden states -> (T,) expert ids via TCAM match.

    Encoding + match are exactly the paper's semantics; by DT construction
    every input matches exactly one row."""
    vals = x.astype(jnp.float32)[:, bits["bit_feat"]]        # (T, W)
    xbits = jnp.where(bits["bit_const"][None, :], 1.0,
                      (vals > bits["bit_thr"][None, :]).astype(jnp.float32))
    mism = xbits @ bits["is0"].T + (1.0 - xbits) @ bits["is1"].T
    row = jnp.argmin(mism, axis=-1)                          # zero-mismatch row
    return bits["classes"][row]
