"""Mamba selective-SSM mixer (jamba's attention-free layers).

Training/prefill runs a *chunked* selective scan: the sequence is processed
in chunks (outer ``lax.scan``) carrying the (B, d_inner, N) state; inside a
chunk the recurrence is a plain time scan.  The chunk structure bounds the
materialized (B, chunk, d_inner, N) discretized tensors — the full-sequence
(B, S, d_inner, N) form would be tens of GB at 4k+ sequence lengths.

Decode is the single-token state update.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..sharding import shard
from .config import ModelConfig

__all__ = ["mamba_mixer", "mamba_decode", "init_mamba_state"]

CHUNK = 256


def _conv_causal(x: jax.Array, w: jax.Array, b: jax.Array,
                 prev: jax.Array | None = None) -> jax.Array:
    """Depthwise causal conv along seq.  x (B,S,Di), w (Di,K), b (Di,);
    prev (B,K-1,Di) carries context across prefill->decode."""
    bsz, s, di = x.shape
    k = w.shape[1]
    if prev is None:
        prev = jnp.zeros((bsz, k - 1, di), x.dtype)
    xp = jnp.concatenate([prev, x], axis=1)                 # (B, S+K-1, Di)
    out = jnp.zeros((bsz, s, di), jnp.float32)
    for i in range(k):                                      # K=4 static unroll
        out = out + xp[:, i : i + s, :].astype(jnp.float32) * w[:, i].astype(
            jnp.float32
        )
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def _ssm_chunk(h0: jax.Array, dA: jax.Array, dBx: jax.Array,
               cmat: jax.Array) -> tuple[jax.Array, jax.Array]:
    """One chunk of the recurrence h_t = dA_t * h_{t-1} + dBx_t.

    h0 (B, Di, N); dA/dBx (B, C, Di, N); cmat (B, C, N).
    Returns (h_final, y (B, C, Di))."""

    def step(h, t):
        da_t, dbx_t, c_t = t
        h = da_t * h + dbx_t                                # (B, Di, N)
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    xs = (dA.transpose(1, 0, 2, 3), dBx.transpose(1, 0, 2, 3),
          cmat.transpose(1, 0, 2))
    h, ys = jax.lax.scan(step, h0, xs)
    return h, ys.transpose(1, 0, 2)                         # (B, C, Di)


def _ssm(x: jax.Array, p: dict, cfg: ModelConfig,
         h0: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """Selective scan over the full sequence in CHUNK pieces.
    x (B, S, Di) post-conv activations; returns (y (B,S,Di), h_final)."""
    bsz, s, di = x.shape
    n = cfg.ssm_state
    dtr = cfg.dt_rank
    xf = x.astype(jnp.float32)

    xdb = jnp.einsum("bsd,dk->bsk", xf, p["x_proj"].astype(jnp.float32))
    dt, bmat, cmat = jnp.split(xdb, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,rd->bsd", dt, p["dt_w"].astype(jnp.float32))
        + p["dt_b"].astype(jnp.float32)
    )                                                        # (B, S, Di)
    dt = shard(dt, "act_batch", "act_seq", "act_dinner")
    a = -jnp.exp(p["A_log"].astype(jnp.float32))             # (Di, N)

    if h0 is None:
        h0 = jnp.zeros((bsz, di, n), jnp.float32)
    chunk = min(CHUNK, s)
    while s % chunk:            # largest divisor of s that is <= CHUNK
        chunk -= 1
    nc = s // chunk

    @jax.checkpoint  # recompute per chunk: peak = one chunk's (B,C,Di,N)
    def outer(h, idx):
        sl = lambda t: jax.lax.dynamic_slice_in_dim(t, idx * chunk, chunk, 1)
        dt_c, b_c, c_c, x_c = sl(dt), sl(bmat), sl(cmat), sl(xf)
        da = jnp.exp(dt_c[..., None] * a[None, None])        # (B,C,Di,N)
        dbx = dt_c[..., None] * b_c[:, :, None, :] * x_c[..., None]
        h, y = _ssm_chunk(h, da, dbx, c_c)
        return h, y

    h, ys = jax.lax.scan(outer, h0, jnp.arange(nc))
    y = ys.transpose(1, 0, 2, 3).reshape(bsz, s, di)
    y = y + xf * p["Dskip"].astype(jnp.float32)[None, None]
    return y.astype(x.dtype), h


def mamba_mixer(
    x: jax.Array,              # (B, S, D) post-norm residual stream
    p: dict,
    cfg: ModelConfig,
    state: tuple | None = None,   # (conv_prev (B,K-1,Di), h (B,Di,N))
    return_state: bool = False,
):
    dt = x.dtype
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(dt))
    xin, z = jnp.split(xz, 2, axis=-1)                       # (B,S,Di) each
    xin = shard(xin, "act_batch", "act_seq", "act_dinner")
    conv_prev = state[0] if state is not None else None
    h0 = state[1] if state is not None else None
    xc = jax.nn.silu(_conv_causal(xin, p["conv_w"], p["conv_b"], conv_prev))
    y, h = _ssm(xc, p, cfg, h0)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(dt))
    out = shard(out, "act_batch", "act_seq", "act_embed")
    if return_state:
        k = cfg.ssm_conv
        if conv_prev is None:
            conv_prev = jnp.zeros(
                (x.shape[0], k - 1, xin.shape[-1]), xin.dtype
            )
        hist = jnp.concatenate([conv_prev, xin], axis=1)     # (B, S+K-1, Di)
        new_conv = hist[:, hist.shape[1] - (k - 1):, :]
        return out, (new_conv, h)
    return out


def init_mamba_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    di, n, k = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    return (
        jnp.zeros((batch, k - 1, di), dtype),
        jnp.zeros((batch, di, n), jnp.float32),
    )


def mamba_decode(x: jax.Array, p: dict, cfg: ModelConfig, state: tuple):
    """Single-token decode: x (B, 1, D) -> (out (B,1,D), new state)."""
    out, new_state = mamba_mixer(x, p, cfg, state=state, return_state=True)
    return out, new_state
