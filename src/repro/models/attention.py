"""Attention: chunked online-softmax ("jnp-flash") for train/prefill, plus a
single-query decode path over KV caches (full or sliding-window ring).

The chunked formulation never materializes the (Sq, Sk) score matrix —
peak memory is O(q_chunk · kv_chunk) per head group — which is what lets the
32k prefill and 500k decode cells fit HBM at compile time.

Known waste (recorded for §Perf): causal masking is applied to full block
products, so causal attention executes ~2x the minimal FLOPs; triangular
block scheduling is a hillclimb item.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30

__all__ = ["flash_attention", "decode_attention"]


def flash_attention(
    q: jax.Array,            # (B, Sq, H, hd)
    k: jax.Array,            # (B, Sk, KV, hd)
    v: jax.Array,            # (B, Sk, KV, hd)
    *,
    causal: bool = True,
    window: int = 0,         # >0: sliding-window attention
    q_offset: int = 0,       # absolute position of q[0] (prefill continuation)
    prefix_len: int = 0,     # bidirectional prefix (paligemma image tokens)
    q_chunk: int = 512,
    kv_chunk: int = 1024,
) -> jax.Array:
    b, sq, h, hd = q.shape
    _, sk, kv, _ = k.shape
    assert h % kv == 0, (h, kv)
    g = h // kv

    def _fit(n, want):  # largest divisor of n that is <= want
        c = min(want, n)
        while n % c:
            c -= 1
        return c

    q_chunk = _fit(sq, q_chunk)
    kv_chunk = _fit(sk, kv_chunk)
    nq, nk = sq // q_chunk, sk // kv_chunk
    scale = hd ** -0.5

    qg = q.reshape(b, nq, q_chunk, kv, g, hd).transpose(1, 0, 2, 3, 4, 5)
    kc = k.reshape(b, nk, kv_chunk, kv, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nk, kv_chunk, kv, hd).transpose(1, 0, 2, 3, 4)

    @jax.checkpoint  # backward recomputes per q-chunk: O(q_chunk·Sk) peak,
    def q_step(_, qi_idx_and_q):  # not O(Sq·Sk) — required for 32k+ cells
        qi_idx, qi = qi_idx_and_q
        q_pos = q_offset + qi_idx * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, kj_idx_and_kv):
            m, l, acc = carry
            kj_idx, kj, vj = kj_idx_and_kv
            kv_pos = kj_idx * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum(
                "bqkgh,bskh->bqkgs", qi.astype(jnp.float32),
                kj.astype(jnp.float32),
            ) * scale
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                c = kv_pos[None, :] <= q_pos[:, None]
                if prefix_len > 0:
                    c |= kv_pos[None, :] < prefix_len
                mask &= c
            if window > 0:
                w = kv_pos[None, :] > q_pos[:, None] - window
                if prefix_len > 0:
                    w |= kv_pos[None, :] < prefix_len
                mask &= w
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bqkgs,bskh->bqkgh", p, vj.astype(jnp.float32)
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, q_chunk, kv, g), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, q_chunk, kv, g), jnp.float32)
        a0 = jnp.zeros((b, q_chunk, kv, g, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), kc, vc)
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    _, out = jax.lax.scan(q_step, None, (jnp.arange(nq), qg))
    # (nq, B, qc, kv, g, hd) -> (B, Sq, H, hd)
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, h, hd)
    return out


def decode_attention(
    q: jax.Array,            # (B, 1, H, hd) — one new token
    cache_k: jax.Array,      # (B, S, KV, hd) — RoPE applied at write time
    cache_v: jax.Array,      # (B, S, KV, hd)
    slot_pos: jax.Array,     # (S,) int32 absolute position per slot, -1 empty
    pos: jax.Array,          # scalar int32 — position of the new token
    *,
    window: int = 0,
) -> jax.Array:
    b, _, h, hd = q.shape
    _, s, kv, _ = cache_k.shape
    g = h // kv
    scale = hd ** -0.5
    qg = q.reshape(b, kv, g, hd)
    # keep cache operands in their storage dtype (bf16) with f32 accumulation:
    # casting the cache would materialize a full f32 copy (2x decode HBM)
    s_ = jnp.einsum(
        "bkgh,bskh->bkgs", qg, cache_k,
        preferred_element_type=jnp.float32,
    ) * scale
    valid = (slot_pos >= 0) & (slot_pos <= pos)
    if window > 0:
        valid &= slot_pos > pos - window
    s_ = jnp.where(valid[None, None, None, :], s_, NEG_INF)
    p = jax.nn.softmax(s_, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", p.astype(cache_v.dtype), cache_v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, hd).astype(q.dtype)
