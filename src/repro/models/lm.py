"""The model stack: train forward / chunked loss / prefill / decode for every
assigned architecture family.

Layers execute as a ``lax.scan`` over repeating *super-blocks*
(``cfg.pattern``): each scan step applies one full pattern instance (e.g.
jamba's 8-layer mamba/attention/MoE interleave) with per-kind stacked params
sliced by the scan — heterogeneous stacks compile to one small HLO body.

Modes:
  forward     — full-sequence activations (training; no cache I/O),
  prefill     — full sequence, emits KV caches / SSM states + last logits,
  decode_step — one token against the caches (the ``serve_step`` the dry-run
                lowers for decode_32k / long_500k cells).

KV caches are ring buffers (slot = pos % cache_len) with a per-slot absolute
position table, which unifies full-window and sliding-window (SWA) decode.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..sharding import current_rules, shard
from .attention import decode_attention, flash_attention
from .config import ModelConfig
from .layers import COMPUTE_DTYPE, mlp, nonparam_norm, rms_norm, rope, rope_table
from .mamba import init_mamba_state, mamba_mixer
from .moe import moe_ffn
from .rwkv import init_rwkv_state, rwkv_channel_mix, rwkv_mixer

__all__ = ["forward", "loss_fn", "prefill", "decode_step", "init_cache",
           "encode_audio"]


@dataclasses.dataclass
class Ctx:
    mode: str                      # "full" | "prefill" | "decode"
    sin: jax.Array | None = None   # rope tables for the current positions
    cos: jax.Array | None = None
    pos: Any = None                # decode: scalar position of the new token
    seq_len: int = 0               # full/prefill: sequence length
    prefix_len: int = 0
    enc_out: jax.Array | None = None   # encdec: encoder activations
    causal: bool = True


def _norm(x, scale, cfg: ModelConfig):
    if cfg.norm_type == "nonparam":
        return nonparam_norm(x, cfg.norm_eps)
    return rms_norm(x, scale, cfg.norm_eps)


def _qk_headnorm(q, p, cfg, name):
    if not cfg.qk_norm:
        return q
    return rms_norm(q, p[name], cfg.norm_eps)


def _cache_len(cfg: ModelConfig, mixer: str, max_seq: int) -> int:
    if mixer == "swa" and cfg.sliding_window > 0:
        return min(cfg.sliding_window, max_seq)
    return max_seq


# ---------------------------------------------------------------------------
# mixers
# ---------------------------------------------------------------------------
def _attention_mixer(kind, h, p, cfg: ModelConfig, ctx: Ctx, cache):
    mixer = kind.split("+")[0]
    window = cfg.sliding_window if mixer == "swa" else 0
    b, s, d = h.shape
    nh, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = h.dtype
    x = _norm(h, p["norm1"], cfg)
    q = jnp.einsum("bsd,dq->bsq", x, p["wq"].astype(dt)).reshape(b, s, nh, hd)
    k = jnp.einsum("bsd,dq->bsq", x, p["wk"].astype(dt)).reshape(b, s, kv, hd)
    v = jnp.einsum("bsd,dq->bsq", x, p["wv"].astype(dt)).reshape(b, s, kv, hd)
    q = shard(q, "act_batch", "act_seq", "act_heads", None)
    k = shard(k, "act_batch", "act_seq", "act_kv_heads", None)
    v = shard(v, "act_batch", "act_seq", "act_kv_heads", None)
    q = _qk_headnorm(q, p, cfg, "q_norm")
    k = _qk_headnorm(k, p, cfg, "k_norm")
    if cfg.rope_theta > 0:
        q = rope(q, ctx.sin, ctx.cos)
        k = rope(k, ctx.sin, ctx.cos)

    new_cache = cache
    if ctx.mode == "full":
        o = flash_attention(q, k, v, causal=ctx.causal, window=window,
                            prefix_len=ctx.prefix_len)
    elif ctx.mode == "prefill":
        o = flash_attention(q, k, v, causal=ctx.causal, window=window,
                            prefix_len=ctx.prefix_len)
        clen = cache["k"].shape[1]
        keep = min(s, clen)
        pos_keep = jnp.arange(keep) + (s - keep)
        slots = pos_keep % clen
        k_c = cache["k"].at[:, slots].set(
            k[:, s - keep:].astype(cache["k"].dtype))
        v_c = cache["v"].at[:, slots].set(
            v[:, s - keep:].astype(cache["v"].dtype))
        sp = cache["slot_pos"].at[slots].set(pos_keep.astype(jnp.int32))
        k_c = shard(k_c, "act_batch", "cache_seq", "act_kv_heads", "act_hd")
        v_c = shard(v_c, "act_batch", "cache_seq", "act_kv_heads", "act_hd")
        new_cache = dict(cache, k=k_c, v=v_c, slot_pos=sp)
    else:  # decode
        clen = cache["k"].shape[1]
        slot = ctx.pos % clen
        k_c = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
        v_c = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
        sp = jax.lax.dynamic_update_slice_in_dim(
            cache["slot_pos"], ctx.pos.astype(jnp.int32)[None], slot, axis=0)
        k_c = shard(k_c, "act_batch", "cache_seq", "act_kv_heads", "act_hd")
        v_c = shard(v_c, "act_batch", "cache_seq", "act_kv_heads", "act_hd")
        new_cache = dict(cache, k=k_c, v=v_c, slot_pos=sp)
        o = decode_attention(q, k_c, v_c, sp, ctx.pos, window=window)

    o = o.reshape(b, s, nh * hd)
    out = jnp.einsum("bsq,qd->bsd", o, p["wo"].astype(dt))
    return h + shard(out, "act_batch", "act_seq", "act_embed"), new_cache


def _cross_mixer(h, p, cfg: ModelConfig, ctx: Ctx, cache):
    """Whisper decoder cross-attention over encoder outputs."""
    b, s, d = h.shape
    nh, hd = cfg.n_heads, cfg.head_dim
    dt = h.dtype
    x = _norm(h, p["norm_x"], cfg)
    q = jnp.einsum("bsd,dq->bsq", x, p["xwq"].astype(dt)).reshape(b, s, nh, hd)
    new_cache = cache
    if ctx.mode == "decode":
        xk, xv = cache["xk"], cache["xv"]
        sp = jnp.arange(xk.shape[1], dtype=jnp.int32)
        o = decode_attention(q, xk, xv, sp, jnp.int32(2**30))
    else:
        enc = ctx.enc_out
        xk = jnp.einsum("bsd,dq->bsq", enc, p["xwk"].astype(dt)).reshape(
            b, enc.shape[1], nh, hd)
        xv = jnp.einsum("bsd,dq->bsq", enc, p["xwv"].astype(dt)).reshape(
            b, enc.shape[1], nh, hd)
        o = flash_attention(q, xk, xv, causal=False)
        if ctx.mode == "prefill":
            new_cache = dict(cache, xk=xk.astype(cache["xk"].dtype),
                             xv=xv.astype(cache["xv"].dtype))
    o = o.reshape(b, s, nh * hd)
    out = jnp.einsum("bsq,qd->bsd", o, p["xwo"].astype(dt))
    return h + shard(out, "act_batch", "act_seq", "act_embed"), new_cache


def _apply_block(kind, h, p, cfg: ModelConfig, ctx: Ctx, cache):
    mixer, ffn = kind.split("+")
    new_cache = dict(cache) if cache is not None else None

    if mixer in ("attn", "swa"):
        h, new_cache = _attention_mixer(kind, h, p, cfg, ctx, new_cache)
    elif mixer == "mamba":
        st = ((new_cache["conv"], new_cache["h"])
              if ctx.mode != "full" else None)
        x = _norm(h, p["norm1"], cfg)
        if ctx.mode == "full":
            h = h + mamba_mixer(x, p, cfg)
        else:
            out, (conv, hst) = mamba_mixer(x, p, cfg, state=st,
                                           return_state=True)
            h = h + out
            new_cache = dict(new_cache, conv=conv.astype(new_cache["conv"].dtype),
                             h=hst)
    elif mixer == "rwkv":
        x = _norm(h, p["norm1"], cfg)
        if ctx.mode == "full":
            h = h + rwkv_mixer(x, p, cfg)
        else:
            st = (new_cache["xa"].astype(x.dtype), new_cache["S"])
            out, (xa, sst) = rwkv_mixer(x, p, cfg, state=st, return_state=True)
            h = h + out
            new_cache = dict(new_cache, xa=xa.astype(new_cache["xa"].dtype),
                             S=sst)
    else:
        raise ValueError(mixer)

    if cfg.is_encdec:
        h, new_cache = _cross_mixer(h, p, cfg, ctx, new_cache)

    x = _norm(h, p["norm2"], cfg)
    if ffn == "mlp":
        h = h + mlp(x, p, cfg.mlp_act)
    elif ffn == "moe":
        h = h + moe_ffn(x, p, cfg)
    elif ffn == "cmix":
        if ctx.mode == "full":
            h = h + rwkv_channel_mix(x, p, cfg)
        else:
            out, xc = rwkv_channel_mix(x, p, cfg,
                                       state=new_cache["xc"].astype(x.dtype),
                                       return_state=True)
            h = h + out
            new_cache = dict(new_cache, xc=xc.astype(new_cache["xc"].dtype))
    else:
        raise ValueError(ffn)
    return h, new_cache


# ---------------------------------------------------------------------------
# FSDP gather-at-use (ZeRO-3): inside the layer scan, re-annotate each weight
# with its FSDP ("embed"/data) dim UNSHARDED while keeping the TP dims.
# GSPMD then all-gathers the *weight* once per layer (weight-sized comm)
# instead of all-reducing *activation*-sized partial sums — measured 50x+
# lower collective bytes on the train cells (EXPERIMENTS.md §Perf).
# ---------------------------------------------------------------------------
_FSDP_DIMS = frozenset({"embed"})
_TP_DIMS = frozenset({"qkv", "mlp", "experts", "vocab", "dinner"})


def _gather_axes(axes: tuple, gather_tp: bool) -> tuple:
    drop = _FSDP_DIMS | (_TP_DIMS if gather_tp else frozenset())
    return tuple(None if a in drop else a for a in axes)


def _gather_fsdp(params, axes_tree):
    rules = current_rules()
    if rules is None or not rules.table.get("_gather_tp"):
        # TP-mapped archs: leave weight resharding to GSPMD (forcing
        # gathered copies regressed qwen3/jamba by 4-8 GiB — §Perf log)
        return params
    return jax.tree.map(
        lambda ax, w: shard(w, *_gather_axes(ax, True)),
        axes_tree, params,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x))


# ---------------------------------------------------------------------------
# the super-block scan
# ---------------------------------------------------------------------------
def _reshape_stacks(cfg: ModelConfig, tree: dict) -> dict:
    """{kind: leaves (total_occ, ...)} -> leaves (n_repeat, occ_k, ...)."""
    out = {}
    for kind, leaves in tree.items():
        occ = len(cfg.kind_positions(kind))
        out[kind] = jax.tree.map(
            lambda a: a.reshape(cfg.n_repeat, occ, *a.shape[1:]), leaves)
    return out


def _scan_blocks(cfg: ModelConfig, params_blocks, caches, h, ctx: Ctx,
                 remat: str = "none"):
    pattern = cfg.pattern
    p_xs = _reshape_stacks(cfg, params_blocks)
    c_xs = None if caches is None else _reshape_stacks(cfg, caches)
    from .params import kind_specs
    gather_axes = {
        kind: {name: spec[1] for name, spec in
               kind_specs(cfg, kind, with_cross=cfg.is_encdec).items()}
        for kind in params_blocks
    }

    occ_per = {kind: len(cfg.kind_positions(kind)) for kind in params_blocks}

    if caches is None:
        def body(h, p_sl):
            counters = {k: 0 for k in p_sl}
            for kind in pattern:
                i = counters[kind]
                counters[kind] += 1
                p_i = jax.tree.map(lambda a: a[i], p_sl[kind])
                p_i = _gather_fsdp(p_i, gather_axes[kind])
                h, _ = _apply_block(kind, h, p_i, cfg, ctx, None)
            return h, None

        if remat == "dots":
            body = jax.checkpoint(
                body,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        elif remat == "full":
            body = jax.checkpoint(body)
        h, _ = jax.lax.scan(body, h, p_xs)
        return h, None

    # Caches are CARRIED (not scanned xs->ys): each step dynamic-updates its
    # layer slice in place, so the loop aliases one cache buffer instead of
    # accumulating a second stacked copy (2x+ decode HBM otherwise).
    def body_c(carry, xs_t):
        h, cstack = carry
        r, p_sl = xs_t
        counters = {k: 0 for k in p_sl}
        for kind in pattern:
            i = counters[kind]
            counters[kind] += 1
            p_i = jax.tree.map(lambda a: a[i], p_sl[kind])
            p_i = _gather_fsdp(p_i, gather_axes[kind])
            idx = r * occ_per[kind] + i
            c_i = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(
                    a, idx, 0, keepdims=False), cstack[kind])
            h, c_out = _apply_block(kind, h, p_i, cfg, ctx, c_i)
            cstack = dict(cstack, **{kind: jax.tree.map(
                lambda full, new: jax.lax.dynamic_update_index_in_dim(
                    full, new.astype(full.dtype), idx, 0),
                cstack[kind], c_out)})
        return (h, cstack), None

    (h, new_caches), _ = jax.lax.scan(
        body_c, (h, caches), (jnp.arange(cfg.n_repeat), p_xs))
    return h, new_caches


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------
def _embed(params, cfg: ModelConfig, tokens, frontend=None):
    table = shard(params["embed"], "vocab", None)     # gather the FSDP dim
    h = table[tokens].astype(COMPUTE_DTYPE)
    if cfg.emb_scale:
        h = h * jnp.asarray(cfg.d_model ** 0.5, COMPUTE_DTYPE)
    if frontend is not None:
        h = jnp.concatenate([frontend.astype(COMPUTE_DTYPE), h], axis=1)
    return shard(h, "act_batch", "act_seq", "act_embed")


def _logits(params, cfg: ModelConfig, h):
    w = (shard(params["embed"], "vocab", None).T if cfg.tie_embeddings
         else shard(params["lm_head"], None, "vocab"))
    logits = jnp.einsum("bsd,dv->bsv", h, w.astype(h.dtype))
    return shard(logits, "act_batch", "act_seq", "act_vocab")


def _rope_tables(cfg: ModelConfig, positions):
    if cfg.rope_theta <= 0:
        return None, None
    return rope_table(positions, cfg.head_dim, cfg.rope_theta)


def encode_audio(params, cfg: ModelConfig, frames, remat: str = "full"):
    """Whisper encoder: frames are stub frontend embeddings (B, S_enc, D)."""
    enc = params["encoder"]
    h = frames.astype(COMPUTE_DTYPE) + enc["pos_emb"][None].astype(COMPUTE_DTYPE)
    h = shard(h, "act_batch", "act_seq", "act_embed")
    cfg_enc = dataclasses.replace(cfg, encoder_layers=0,
                                  n_layers=cfg.encoder_layers,
                                  pattern=("attn+mlp",))
    ctx = Ctx(mode="full", causal=False, seq_len=h.shape[1])
    sin, cos = _rope_tables(cfg, jnp.arange(h.shape[1]))
    ctx.sin, ctx.cos = sin, cos
    h, _ = _scan_blocks(cfg_enc, enc["blocks"], None, h, ctx, remat=remat)
    return _norm(h, enc["final_norm"], cfg)


def forward(params, cfg: ModelConfig, tokens, *, frontend=None, frames=None,
            remat: str = "dots"):
    """Full-sequence activations -> logits (training / evaluation)."""
    enc_out = None
    if cfg.is_encdec:
        enc_out = encode_audio(params, cfg, frames, remat=remat)
    h = _embed(params, cfg, tokens, frontend)
    s = h.shape[1]
    positions = jnp.arange(s)
    sin, cos = _rope_tables(cfg, positions)
    if cfg.is_encdec:
        h = h + params["dec_pos_emb"][None, :s].astype(h.dtype)
    ctx = Ctx(mode="full", sin=sin, cos=cos, seq_len=s,
              prefix_len=cfg.frontend_tokens, enc_out=enc_out)
    h, _ = _scan_blocks(cfg, params["blocks"], None, h, ctx, remat=remat)
    h = _norm(h, params["final_norm"], cfg)
    return _logits(params, cfg, h)


def loss_fn(params, cfg: ModelConfig, batch, *, remat: str = "dots",
            loss_chunk: int = 1024):
    """Next-token CE with seq-chunked logits (peak memory ~ B×chunk×V)."""
    enc_out = None
    if cfg.is_encdec:
        enc_out = encode_audio(params, cfg, batch["frames"], remat=remat)
    h = _embed(params, cfg, batch["tokens"], batch.get("patches"))
    s = h.shape[1]
    sin, cos = _rope_tables(cfg, jnp.arange(s))
    if cfg.is_encdec:
        h = h + params["dec_pos_emb"][None, :s].astype(h.dtype)
    ctx = Ctx(mode="full", sin=sin, cos=cos, seq_len=s,
              prefix_len=cfg.frontend_tokens, enc_out=enc_out)
    h, _ = _scan_blocks(cfg, params["blocks"], None, h, ctx, remat=remat)
    h = _norm(h, params["final_norm"], cfg)

    labels = batch["labels"]
    if cfg.frontend_tokens:
        # frontend positions carry no next-token loss
        pad = jnp.full((labels.shape[0], cfg.frontend_tokens), -1, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)

    w = (shard(params["embed"], "vocab", None).T if cfg.tie_embeddings
         else shard(params["lm_head"], None, "vocab"))
    chunk = min(loss_chunk, s)
    assert s % chunk == 0
    nc = s // chunk
    b = h.shape[0]
    h_c = h.reshape(b, nc, chunk, -1).transpose(1, 0, 2, 3)
    l_c = labels.reshape(b, nc, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def chunk_loss(hc, lc):
        logits = jnp.einsum("bsd,dv->bsv", hc, w.astype(hc.dtype))
        logits = shard(logits, "act_batch", "act_seq", "act_vocab")
        logits = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lc, 0)[..., None], axis=-1
        )[..., 0]
        valid = lc >= 0
        return ((lse - gold) * valid).sum(), valid.sum()

    def body(carry, xs):
        tot, cnt = carry
        loss, n = chunk_loss(*xs)
        return (tot + loss, cnt + n), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.float32(0.0), jnp.int32(0)), (h_c, l_c))
    loss = tot / jnp.maximum(cnt, 1)
    return loss, {"loss": loss, "tokens": cnt}


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=COMPUTE_DTYPE) -> dict:
    """Stacked per-kind decode caches (see module docstring)."""
    caches = {}
    nh, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    for kind in cfg.kinds:
        occ = len(cfg.kind_positions(kind)) * cfg.n_repeat
        mixer = kind.split("+")[0]
        leaves: dict = {}
        if mixer in ("attn", "swa"):
            clen = _cache_len(cfg, mixer, max_seq)
            leaves["k"] = jnp.zeros((occ, batch, clen, kv, hd), dtype)
            leaves["v"] = jnp.zeros((occ, batch, clen, kv, hd), dtype)
            leaves["slot_pos"] = jnp.full((occ, clen), -1, jnp.int32)
        elif mixer == "mamba":
            conv, hst = init_mamba_state(cfg, batch, dtype)
            leaves["conv"] = jnp.tile(conv[None], (occ, 1, 1, 1))
            leaves["h"] = jnp.tile(hst[None], (occ, 1, 1, 1))
        elif mixer == "rwkv":
            xa, sst, xc = init_rwkv_state(cfg, batch, dtype)
            leaves["xa"] = jnp.tile(xa[None], (occ, 1, 1))
            leaves["S"] = jnp.tile(sst[None], (occ, 1, 1, 1, 1))
            leaves["xc"] = jnp.tile(xc[None], (occ, 1, 1))
        if kind.split("+")[1] == "cmix" and "xc" not in leaves:
            leaves["xc"] = jnp.zeros((occ, batch, cfg.d_model), dtype)
        if cfg.is_encdec:
            leaves["xk"] = jnp.zeros((occ, batch, cfg.encoder_seq, nh, hd),
                                     dtype)
            leaves["xv"] = jnp.zeros((occ, batch, cfg.encoder_seq, nh, hd),
                                     dtype)
        caches[kind] = leaves
    return caches


def prefill(params, cfg: ModelConfig, tokens, caches, *, frontend=None,
            frames=None):
    """Full-sequence forward that fills the caches; returns (last-token
    logits, caches)."""
    enc_out = None
    if cfg.is_encdec:
        enc_out = encode_audio(params, cfg, frames)
    h = _embed(params, cfg, tokens, frontend)
    s = h.shape[1]
    sin, cos = _rope_tables(cfg, jnp.arange(s))
    if cfg.is_encdec:
        h = h + params["dec_pos_emb"][None, :s].astype(h.dtype)
    ctx = Ctx(mode="prefill", sin=sin, cos=cos, seq_len=s,
              prefix_len=cfg.frontend_tokens, enc_out=enc_out)
    h, caches = _scan_blocks(cfg, params["blocks"], caches, h, ctx)
    h = _norm(h, params["final_norm"], cfg)
    return _logits(params, cfg, h[:, -1:]), caches


def decode_step(params, cfg: ModelConfig, token, caches, pos):
    """One decode step: token (B, 1) int32, pos scalar int32 -> (logits
    (B, 1, V), new caches)."""
    h = _embed(params, cfg, token)
    sin, cos = _rope_tables(cfg, pos[None].astype(jnp.int32))
    if cfg.is_encdec:
        pe = jax.lax.dynamic_slice_in_dim(params["dec_pos_emb"], pos, 1, 0)
        h = h + pe[None].astype(h.dtype)
    ctx = Ctx(mode="decode", sin=sin, cos=cos, pos=pos)
    h, caches = _scan_blocks(cfg, params["blocks"], caches, h, ctx)
    h = _norm(h, params["final_norm"], cfg)
    return _logits(params, cfg, h), caches
