"""h2o-danube-1.8b [dense]: llama+mistral mix with sliding-window attention
[arXiv:2401.16818].

24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000, SWA window 4096 —
sub-quadratic, so it runs the long_500k cell with a 4096-slot ring cache.
"""
import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o_danube_1p8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    head_dim=80,
    d_ff=6912,
    vocab_size=32000,
    pattern=("swa+mlp",),
    sliding_window=4096,
    mlp_act="silu",
    rope_theta=10_000.0,
    tie_embeddings=False,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, sliding_window=16,
    )
