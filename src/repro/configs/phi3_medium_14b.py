"""phi3-medium-14b [dense]: RoPE + SwiGLU + GQA [arXiv:2404.14219].

40L d_model=5120 40H (GQA kv=10) d_ff=17920 vocab=100352.
40 heads / 10 kv-heads do not divide the 16-way tensor axis: attention
activations fall back to replicated (rules drop the axis) while the merged
QKV projections stay sharded — see EXPERIMENTS.md §Perf for the padded-head
hillclimb.
"""
import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3_medium_14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=10,
    head_dim=128,
    d_ff=17920,
    vocab_size=100352,
    pattern=("attn+mlp",),
    mlp_act="silu",
    rope_theta=10_000.0,
    tie_embeddings=False,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512,
    )
