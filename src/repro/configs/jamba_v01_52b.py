"""jamba-v0.1-52b [hybrid]: Mamba + attention 1:7 interleave, MoE every
other layer (16 experts top-2) [arXiv:2403.19887].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536.  The repeating
8-layer Jamba block places the attention layer at offset 4 and MoE on odd
offsets — exactly the published 1:7 attn:mamba ratio with e=16/k=2.
"""
import dataclasses

from ..models.config import ModelConfig

_PATTERN = (
    "mamba+mlp", "mamba+moe", "mamba+mlp", "mamba+moe",
    "attn+mlp", "mamba+moe", "mamba+mlp", "mamba+moe",
)

CONFIG = ModelConfig(
    name="jamba_v01_52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    pattern=_PATTERN,
    n_experts=16,
    experts_per_token=2,
    mlp_act="silu",
    rope_theta=10_000.0,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    moe_groups=2,
    tie_embeddings=False,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, n_experts=4, experts_per_token=2,
    )
