"""Assigned architecture configs (+ input-shape cells and skip rules).

Every architecture is selectable via ``--arch <id>``; each has:
  * ``CONFIG``        — the exact full-size published config,
  * ``reduced()``     — a tiny same-family config for CPU smoke tests.

Shape cells (per assignment):
  train_4k    seq 4096,   global batch 256  -> train_step
  prefill_32k seq 32768,  global batch 32   -> prefill (inference)
  decode_32k  seq 32768,  global batch 128  -> serve_step (1 token, 32k cache)
  long_500k   seq 524288, global batch 1    -> serve_step; sub-quadratic only

``long_500k`` runs for jamba (hybrid), rwkv6 (O(1) state) and h2o-danube
(SWA window 4096); pure full-attention archs skip it (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
import importlib

from ..models.config import ModelConfig

__all__ = ["ARCHS", "SHAPES", "get_config", "get_reduced", "shape_cells",
           "Shape"]

ARCHS = (
    "paligemma_3b",
    "jamba_v01_52b",
    "dbrx_132b",
    "qwen3_moe_235b_a22b",
    "rwkv6_1p6b",
    "olmo_1b",
    "gemma_7b",
    "phi3_medium_14b",
    "h2o_danube_1p8b",
    "whisper_small",
)

# archs with sub-quadratic sequence mixing (run long_500k)
SUBQUADRATIC = {"jamba_v01_52b", "rwkv6_1p6b", "h2o_danube_1p8b"}


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    step: str          # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": Shape("train_4k", 4096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32768, 128, "decode"),
    "long_500k": Shape("long_500k", 524288, 1, "decode"),
}


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f".{arch}", __package__)
    return mod.CONFIG


def get_reduced(arch: str) -> ModelConfig:
    mod = importlib.import_module(f".{arch}", __package__)
    return mod.reduced()


def shape_cells(arch: str) -> list[Shape]:
    """The shape cells this arch runs (applying the long_500k skip rule)."""
    out = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if arch in SUBQUADRATIC:
        out.append(SHAPES["long_500k"])
    return out


# Per-arch training-cell memory policy, sized for 16 GiB/chip HBM (v5e):
# microbatch accumulation bounds the stacked per-layer activation residuals
# (B_local = 256/data_shards/accum), bf16 params+moments halve the static
# state for the 100B+ MoE models (see EXPERIMENTS.md §Dry-run).
TRAIN_SETTINGS: dict = {
    "paligemma_3b": dict(accum=2),
    "jamba_v01_52b": dict(accum=16, mu_dtype="bfloat16", nu_dtype="bfloat16",
                          accum_dtype="bfloat16"),
    "dbrx_132b": dict(accum=16, mu_dtype="bfloat16", nu_dtype="bfloat16",
                      accum_dtype="bfloat16"),
    "qwen3_moe_235b_a22b": dict(accum=16, param_dtype="bfloat16",
                                mu_dtype="bfloat16", nu_dtype="bfloat16",
                                accum_dtype="bfloat16"),
    "rwkv6_1p6b": dict(accum=1, dp_only=True),
    "olmo_1b": dict(accum=1, dp_only=True),
    "gemma_7b": dict(accum=4),
    "phi3_medium_14b": dict(accum=8),
    "h2o_danube_1p8b": dict(accum=1, dp_only=True),
    "whisper_small": dict(accum=1, dp_only=True),
}


def train_settings(arch: str) -> dict:
    return dict(TRAIN_SETTINGS.get(arch, {}))
