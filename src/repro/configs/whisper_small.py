"""whisper-small [audio]: encoder-decoder backbone; conv frontend is a STUB
per assignment (``input_specs`` provides 1500 precomputed frame embeddings)
[arXiv:2212.04356].

12L encoder + 12L decoder, d_model=768 12H (MHA) d_ff=3072 vocab=51865.
Learned positional embeddings (no RoPE); decode shapes mechanically extend
the decoder position table to 32k (backbone-only per assignment).
"""
import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper_small",
    family="audio",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=51865,
    pattern=("attn+mlp",),
    mlp_act="gelu",
    rope_theta=0.0,       # learned positional embeddings
    encoder_layers=12,
    encoder_seq=1500,
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512, encoder_layers=2, encoder_seq=24,
    )
