"""qwen3-moe-235b-a22b [moe]: 128 experts top-8, fine-grained experts,
qk-norm [hf:Qwen/Qwen3-30B-A3B scaled per assignment].

94L d_model=4096 64H (GQA kv=4) per-expert d_ff=1536 vocab=151936.
"""
import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3_moe_235b_a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab_size=151936,
    pattern=("attn+moe",),
    n_experts=128,
    experts_per_token=8,
    moe_d_ff=1536,
    qk_norm=True,
    mlp_act="silu",
    rope_theta=1_000_000.0,
    moe_groups=8,
    tie_embeddings=False,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=64, moe_d_ff=64, vocab_size=512, n_experts=8,
        experts_per_token=2,
    )
