"""dbrx-132b [moe]: 16 experts top-4, fine-grained MoE
[hf:databricks/dbrx-base].

40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352; every layer is MoE.
"""
import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="dbrx_132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    pattern=("attn+moe",),
    n_experts=16,
    experts_per_token=4,
    moe_d_ff=10752,
    mlp_act="silu",
    rope_theta=500_000.0,
    moe_groups=4,
    tie_embeddings=False,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, moe_d_ff=128, vocab_size=512, n_experts=4,
        experts_per_token=2,
    )
