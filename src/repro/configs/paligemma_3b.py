"""paligemma-3b [vlm]: SigLIP stub frontend + gemma backbone.

18L d_model=2048 8H (GQA kv=1 => MQA) d_ff=16384 vocab=257216, head_dim=256,
GeGLU, embedding scaling [arXiv:2407.07726].  The vision frontend is a STUB
per assignment: ``input_specs`` provides 256 precomputed patch embeddings
that the backbone attends to bidirectionally (prefix-LM masking).
"""
import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="paligemma_3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    pattern=("attn+mlp",),
    mlp_act="gelu",
    rope_theta=10_000.0,
    frontend_tokens=256,
    tie_embeddings=True,
    emb_scale=True,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab_size=512, frontend_tokens=8,
    )
