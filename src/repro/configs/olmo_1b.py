"""olmo-1b [dense]: non-parametric LayerNorm [arXiv:2402.00838].

16L d_model=2048 16H (MHA kv=16) d_ff=8192 vocab=50304.
"""
import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmo_1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=8192,
    vocab_size=50304,
    pattern=("attn+mlp",),
    norm_type="nonparam",
    mlp_act="silu",
    rope_theta=10_000.0,
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512,
    )
