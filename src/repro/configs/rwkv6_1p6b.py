"""rwkv6-1.6b "Finch" [ssm]: attention-free, data-dependent decay
[arXiv:2404.05892].

24L d_model=2048 d_ff=7168 vocab=65536; 32 wkv heads of dim 64; RWKV
channel-mix as the FFN.
"""
import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6_1p6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,          # wkv heads (d_model / rwkv_head_dim)
    n_kv_heads=32,
    head_dim=64,
    d_ff=7168,
    vocab_size=65536,
    pattern=("rwkv+cmix",),
    rwkv_head_dim=64,
    rope_theta=0.0,      # attention-free
    tie_embeddings=False,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512, rwkv_head_dim=16,
    )
