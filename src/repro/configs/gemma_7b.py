"""gemma-7b [dense]: GeGLU, head_dim=256, embedding scaling
[arXiv:2403.08295].

28L d_model=3072 16H (MHA kv=16) d_ff=24576 vocab=256000.
"""
import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma_7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    pattern=("attn+mlp",),
    mlp_act="gelu",
    rope_theta=10_000.0,
    tie_embeddings=True,
    emb_scale=True,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512,
    )
