"""Production serving engine for compiled DT2CAM models.

The paper's headline figure — hundreds of millions of decisions per second,
pipelined — is a *serving* claim; this package is the deployment half of the
reproduction: a batched streaming inference engine on the Pallas TCAM
kernels, reachable from one line:

    >>> from repro.serve import TCAMServer
    >>> with TCAMServer(model.compiled) as server:
    ...     preds = [r.prediction for r in server.serve(X)]
    ...     stats = server.metrics()

  engine.py   — TCAMServer: queue, worker, futures, engine fallback, metrics,
                BIST/repair/canary wiring + circuit breaker
  batching.py — BucketPolicy (padded batch shapes) + AdaptiveBatcher
                (flush on max-batch or deadline)
  cache.py    — CompileCache: one jit compile per (bucket, engine, layout)
  metrics.py  — counters + p50/p99 latency + modelled nJ/dec, M dec/s
  errors.py   — typed serving failures (Rejected / DeadlineExceeded /
                ComputeFailed); every Future resolves with one or a result

``TCAMServer`` also serves multi-bank forests: constructed with a
``repro.forest.CompiledForest`` it shards each batch across TCAM banks
(pipelined batched kernels, per-bank BIST/repair, ensemble vote
aggregation) behind the exact same submit/serve/metrics API.

Zero-downtime model updates: ``TCAMServer.stage()`` loads a candidate model
into a shadow slot that mirrors a fraction of live traffic;
``TCAMServer.promote()`` gates on shadow disagreement + the candidate's own
canary and atomically swaps it live (``rollback()`` reverts).  The registry /
delta-reprogramming half of that story lives in ``repro.lifecycle``.

Fault tolerance across chips (majority voting) lives in
``repro.reliability.ReplicatedServer``.
"""
from .batching import AdaptiveBatcher, BucketPolicy
from .cache import CompileCache
from .engine import PromotionReport, RequestResult, ServeConfig, TCAMServer
from .errors import ComputeFailed, DeadlineExceeded, Rejected, ServingError
from .metrics import LatencyStats, ServeMetrics

__all__ = [
    "AdaptiveBatcher", "BucketPolicy", "CompileCache",
    "PromotionReport", "RequestResult", "ServeConfig", "TCAMServer",
    "LatencyStats", "ServeMetrics",
    "ServingError", "Rejected", "DeadlineExceeded", "ComputeFailed",
]
