"""Serving metrics: counters, latency percentiles, and the modelled-hardware
figures of merit (nJ/decision, M decisions/s) the paper reports.

``LatencyStats`` keeps a bounded ring of samples; percentiles are computed on
demand.  ``ServeMetrics`` aggregates everything a load test needs into one
``snapshot()`` dict (JSON-serializable — the serve benchmark dumps it as-is).
"""
from __future__ import annotations

import threading

import numpy as np

__all__ = ["LatencyStats", "ServeMetrics"]


class LatencyStats:
    """Bounded-reservoir latency recorder (seconds in, percentiles out)."""

    def __init__(self, capacity: int = 16384) -> None:
        self._buf = np.zeros(capacity, np.float64)
        self._n = 0          # total recorded (may exceed capacity)
        self._lock = threading.Lock()

    def record(self, seconds: float) -> None:
        with self._lock:
            self._buf[self._n % self._buf.size] = seconds
            self._n += 1

    def record_many(self, seconds: np.ndarray) -> None:
        for s in np.asarray(seconds, np.float64).ravel():
            self.record(float(s))

    @property
    def count(self) -> int:
        return self._n

    def _samples(self) -> np.ndarray:
        with self._lock:
            return self._buf[: min(self._n, self._buf.size)].copy()

    def percentile(self, q: float) -> float:
        s = self._samples()
        return float(np.percentile(s, q)) if s.size else float("nan")

    @property
    def p50(self) -> float:
        return self.percentile(50.0)

    @property
    def p99(self) -> float:
        return self.percentile(99.0)

    @property
    def mean(self) -> float:
        s = self._samples()
        return float(s.mean()) if s.size else float("nan")

    def summary_ms(self) -> dict[str, float]:
        return {
            "p50_ms": self.p50 * 1e3,
            "p99_ms": self.p99 * 1e3,
            "mean_ms": self.mean * 1e3,
            "count": float(self.count),
        }


class ServeMetrics:
    """Aggregated serving counters + latency stats.

    Latency is split the way serving systems report it: *queue* (enqueue ->
    batch formation) and *compute* (batch dispatch -> device results ready);
    a request's end-to-end latency is queue + compute of its batch.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.requests_enqueued = 0
        self.requests_served = 0
        self.batches = 0
        self.deadline_flushes = 0     # batches emitted by timeout, not by fill
        self.padded_slots = 0         # Σ (bucket - actual batch size)
        self.engine_fallbacks = 0     # illegal engine requests downgraded
        self.energy_j = 0.0           # Σ modelled energy of served decisions
        self.active_evals = 0         # Σ modelled active row-division evals
        # -- reliability / protection counters --------------------------------
        self.shed = 0                 # requests rejected at admission (queue full)
        self.deadline_exceeded = 0    # requests expired in queue before dispatch
        self.retries = 0              # transient compute failures retried
        self.compute_failures = 0     # batches failed after retry budget
        self.canary_runs = 0
        self.canary_failures = 0      # canary accuracy below threshold
        self.breaker_trips = 0
        self.repairs = 0              # repair attempts (BIST + spare remap)
        self.rows_repaired = 0
        self.last_canary_acc = float("nan")
        # -- degradation (drift scrub / refresh) -------------------------------
        self.scrub_passes = 0         # maintenance passes executed
        self.rows_scrubbed = 0        # Σ rows refreshed across passes
        self.scrub_energy_j = 0.0     # Σ modelled refresh write energy
        self.scrub_pulses = 0         # Σ refresh program pulses (endurance)
        # -- lifecycle (shadow deployment / promotion) -------------------------
        self.stages = 0               # candidates staged into the shadow slot
        self.shadow_batches = 0       # live batches mirrored to the candidate
        self.shadow_requests = 0      # requests the candidate shadow-served
        self.shadow_disagreements = 0  # candidate != live predictions
        self.promotions = 0           # successful atomic swaps
        self.promotion_failures = 0   # promote() gates rejected the candidate
        self.rollbacks = 0            # explicit rollback() calls honored
        self.queue = LatencyStats()
        self.compute = LatencyStats()
        self.total = LatencyStats()

    def on_enqueue(self, n: int = 1) -> None:
        with self._lock:
            self.requests_enqueued += n

    def on_shed(self, n: int = 1) -> None:
        with self._lock:
            self.shed += n

    def on_deadline_exceeded(self, n: int = 1) -> None:
        with self._lock:
            self.deadline_exceeded += n

    def on_retry(self) -> None:
        with self._lock:
            self.retries += 1

    def on_compute_failure(self) -> None:
        with self._lock:
            self.compute_failures += 1

    def on_canary(self, ok: bool, accuracy: float) -> None:
        with self._lock:
            self.canary_runs += 1
            self.canary_failures += int(not ok)
            self.last_canary_acc = accuracy

    def on_trip(self) -> None:
        with self._lock:
            self.breaker_trips += 1

    def on_repair(self, rows: int) -> None:
        with self._lock:
            self.repairs += 1
            self.rows_repaired += rows

    def on_scrub(self, rows: int, energy_j: float, pulses: int) -> None:
        with self._lock:
            self.scrub_passes += 1
            self.rows_scrubbed += rows
            self.scrub_energy_j += energy_j
            self.scrub_pulses += pulses

    def on_stage(self) -> None:
        with self._lock:
            self.stages += 1

    def on_shadow(self, n: int, disagreements: int) -> None:
        with self._lock:
            self.shadow_batches += 1
            self.shadow_requests += n
            self.shadow_disagreements += disagreements

    def on_promotion(self, ok: bool) -> None:
        with self._lock:
            self.promotions += int(ok)
            self.promotion_failures += int(not ok)

    def on_rollback(self) -> None:
        with self._lock:
            self.rollbacks += 1

    def on_batch(
        self,
        n: int,
        bucket: int,
        *,
        deadline_flush: bool,
        energy_j: float,
        active_evals: int,
    ) -> None:
        with self._lock:
            self.requests_served += n
            self.batches += 1
            self.deadline_flushes += int(deadline_flush)
            self.padded_slots += bucket - n
            self.energy_j += energy_j
            self.active_evals += active_evals

    def on_fallback(self) -> None:
        with self._lock:
            self.engine_fallbacks += 1

    def snapshot(self, **extra: float) -> dict:
        """One JSON-ready dict: counters, latency summaries, and whatever
        engine-level extras (hw model numbers, cache stats) are passed in."""
        with self._lock:
            served = self.requests_served
            out = {
                "requests_enqueued": self.requests_enqueued,
                "requests_served": served,
                "batches": self.batches,
                "deadline_flushes": self.deadline_flushes,
                "padded_slots": self.padded_slots,
                "engine_fallbacks": self.engine_fallbacks,
                "mean_batch_fill": (
                    served / max(1, served + self.padded_slots)
                ),
                "modelled_nj_per_dec": (
                    self.energy_j / served * 1e9 if served else float("nan")
                ),
                "active_evals": self.active_evals,
                "reliability": {
                    "shed": self.shed,
                    "deadline_exceeded": self.deadline_exceeded,
                    "retries": self.retries,
                    "compute_failures": self.compute_failures,
                    "canary_runs": self.canary_runs,
                    "canary_failures": self.canary_failures,
                    "breaker_trips": self.breaker_trips,
                    "repairs": self.repairs,
                    "rows_repaired": self.rows_repaired,
                    "last_canary_acc": self.last_canary_acc,
                },
                "degradation": {
                    "scrub_passes": self.scrub_passes,
                    "rows_scrubbed": self.rows_scrubbed,
                    "scrub_energy_j": self.scrub_energy_j,
                    "scrub_pulses": self.scrub_pulses,
                },
                "lifecycle": {
                    "stages": self.stages,
                    "shadow_batches": self.shadow_batches,
                    "shadow_requests": self.shadow_requests,
                    "shadow_disagreements": self.shadow_disagreements,
                    "shadow_disagreement_rate": (
                        self.shadow_disagreements / self.shadow_requests
                        if self.shadow_requests else 0.0
                    ),
                    "promotions": self.promotions,
                    "promotion_failures": self.promotion_failures,
                    "rollbacks": self.rollbacks,
                },
            }
        out["queue_latency"] = self.queue.summary_ms()
        out["compute_latency"] = self.compute.summary_ms()
        out["total_latency"] = self.total.summary_ms()
        out.update(extra)
        return out
