"""Typed serving failures.

Every submitted request resolves — either with a ``RequestResult`` or with
one of these exceptions on its Future.  Callers can branch on the type:

* ``Rejected`` — load shedding: the bounded request queue was full at submit
  time (``ServeConfig.max_queue``).  Retry later / elsewhere.
* ``DeadlineExceeded`` — the request sat in the queue past its per-request
  deadline (``ServeConfig.request_timeout_s``) and was dropped at batch
  formation instead of being served late.
* ``ComputeFailed`` — the batch compute raised even after
  ``ServeConfig.max_retries`` retry-with-backoff attempts; the original
  exception is chained as ``__cause__``.
"""
from __future__ import annotations

__all__ = ["ServingError", "Rejected", "DeadlineExceeded", "ComputeFailed"]


class ServingError(Exception):
    """Base class for typed serving failures."""


class Rejected(ServingError):
    """Request shed at admission: the bounded queue was full."""


class DeadlineExceeded(ServingError):
    """Request expired in the queue before its batch was formed."""


class ComputeFailed(ServingError):
    """Batch compute failed after exhausting its retry budget."""
