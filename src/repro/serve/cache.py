"""Warm compile cache for the serving engine.

jit recompiles are the serving tail-latency killer: every new input shape
costs a trace + XLA compile (hundreds of ms in interpret mode, more on TPU).
The engine therefore funnels every batch through a ``BucketPolicy`` shape and
memoizes one compiled callable per ``(bucket, engine, layout_id)``.  Total
compiles over a server's lifetime are bounded by
``len(buckets) x len(engines)`` per layout — the serve smoke test asserts
exactly this via the hit/miss counters kept here.

The cache is optionally *bounded*: with ``maxsize`` set, the least recently
used entry is evicted once the table is full (``evictions`` counts them), so
a long-lived server cycling through layouts (repair re-keys, lifecycle
promotions) cannot grow its compiled-function table without limit.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Any, Callable, Optional

__all__ = ["CompileCache"]


class CompileCache:
    """Memoize compiled batch functions keyed ``(bucket, engine, layout_id)``.

    ``builder(bucket, engine)`` is invoked exactly once per distinct live key
    (the layout is fixed per cache instance; ``layout_id`` keys guard against
    accidental sharing across layouts).  Thread-safe: the builder runs under
    the cache lock so concurrent workers never double-compile a key.

    ``maxsize=None`` (default) keeps every entry; an integer bounds the table
    with LRU eviction — an evicted key rebuilds (a fresh miss) on next use.
    """

    def __init__(self, builder: Callable[[int, str], Callable[..., Any]],
                 layout_id: str, *, maxsize: Optional[int] = None) -> None:
        if maxsize is not None and maxsize < 1:
            raise ValueError(f"maxsize must be >= 1 or None, got {maxsize}")
        self._builder = builder
        self._layout_id = layout_id
        self._maxsize = maxsize
        self._fns: OrderedDict[tuple[int, str, str], Callable[..., Any]] = \
            OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, bucket: int, engine: str) -> Callable[..., Any]:
        key = (bucket, engine, self._layout_id)
        with self._lock:
            fn = self._fns.get(key)
            if fn is None:
                self.misses += 1
                fn = self._builder(bucket, engine)
                self._fns[key] = fn
                if (self._maxsize is not None
                        and len(self._fns) > self._maxsize):
                    self._fns.popitem(last=False)
                    self.evictions += 1
            else:
                self.hits += 1
                self._fns.move_to_end(key)
            return fn

    def __len__(self) -> int:
        return len(self._fns)

    def stats(self) -> dict[str, int]:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "size": len(self),
            "maxsize": self._maxsize,
        }
