"""Warm compile cache for the serving engine.

jit recompiles are the serving tail-latency killer: every new input shape
costs a trace + XLA compile (hundreds of ms in interpret mode, more on TPU).
The engine therefore funnels every batch through a ``BucketPolicy`` shape and
memoizes one compiled callable per ``(bucket, engine, layout_id)``.  Total
compiles over a server's lifetime are bounded by
``len(buckets) x len(engines)`` per layout — the serve smoke test asserts
exactly this via the hit/miss counters kept here.
"""
from __future__ import annotations

import threading
from typing import Any, Callable

__all__ = ["CompileCache"]


class CompileCache:
    """Memoize compiled batch functions keyed ``(bucket, engine, layout_id)``.

    ``builder(bucket, engine)`` is invoked exactly once per distinct key (the
    layout is fixed per cache instance; ``layout_id`` keys guard against
    accidental sharing across layouts).  Thread-safe: the builder runs under
    the cache lock so concurrent workers never double-compile a key.
    """

    def __init__(self, builder: Callable[[int, str], Callable[..., Any]],
                 layout_id: str) -> None:
        self._builder = builder
        self._layout_id = layout_id
        self._fns: dict[tuple[int, str, str], Callable[..., Any]] = {}
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get(self, bucket: int, engine: str) -> Callable[..., Any]:
        key = (bucket, engine, self._layout_id)
        with self._lock:
            fn = self._fns.get(key)
            if fn is None:
                self.misses += 1
                fn = self._builder(bucket, engine)
                self._fns[key] = fn
            else:
                self.hits += 1
            return fn

    def __len__(self) -> int:
        return len(self._fns)

    def stats(self) -> dict[str, int]:
        return {"hits": self.hits, "misses": self.misses, "size": len(self)}
