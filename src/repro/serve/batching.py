"""Adaptive batch formation for the TCAM serving engine.

Two pure-logic pieces (no threads, injected clock — unit-testable):

* ``BucketPolicy`` — the fixed ladder of padded batch shapes.  Every batch is
  zero-padded up to the smallest bucket that fits, so the jit compile cache
  sees a bounded set of input shapes: at most ``len(buckets)`` compiles per
  (engine, layout), no matter what request sizes arrive.
* ``AdaptiveBatcher`` — a FIFO of pending requests with the classic serving
  flush rule: emit a batch as soon as ``max_batch`` requests are waiting
  (throughput bound) or the *oldest* pending request has waited
  ``max_delay_s`` (tail-latency bound).  With a per-request ``timeout_s``
  the batcher is also *expiry-aware*: ``deadline()`` wakes the worker at
  the earlier of flush-due and first-expiry, and ``pop_expired`` removes
  dead requests so they are failed promptly instead of squatting on
  bounded-queue capacity until the next flush.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Deque, Optional

__all__ = ["BucketPolicy", "AdaptiveBatcher"]


@dataclasses.dataclass(frozen=True)
class BucketPolicy:
    """Power-of-two padding buckets ``min_bucket, 2·min_bucket, ..`` capped
    (and always terminated) at ``max_batch``."""

    max_batch: int = 256
    min_bucket: int = 8

    def __post_init__(self) -> None:
        if self.max_batch < 1 or self.min_bucket < 1:
            raise ValueError("max_batch and min_bucket must be >= 1")
        if self.min_bucket > self.max_batch:
            raise ValueError("min_bucket must be <= max_batch")

    @property
    def buckets(self) -> tuple[int, ...]:
        out = []
        b = self.min_bucket
        while b < self.max_batch:
            out.append(b)
            b *= 2
        out.append(self.max_batch)
        return tuple(out)

    def bucket_for(self, n: int) -> int:
        """Smallest bucket >= n (n must be <= max_batch)."""
        if not 1 <= n <= self.max_batch:
            raise ValueError(f"batch size {n} outside [1, {self.max_batch}]")
        for b in self.buckets:
            if b >= n:
                return b
        return self.max_batch  # unreachable; keeps mypy honest


@dataclasses.dataclass
class _Pending:
    item: Any
    t_enqueue: float


class AdaptiveBatcher:
    """FIFO with flush-on-max-batch-or-deadline semantics, optionally aware
    of a per-request queue timeout (``timeout_s``)."""

    def __init__(self, max_batch: int, max_delay_s: float,
                 timeout_s: Optional[float] = None) -> None:
        if max_delay_s < 0:
            raise ValueError("max_delay_s must be >= 0")
        if timeout_s is not None and timeout_s < 0:
            raise ValueError("timeout_s must be >= 0")
        self.max_batch = max_batch
        self.max_delay_s = max_delay_s
        self.timeout_s = timeout_s
        self._q: Deque[_Pending] = deque()

    def __len__(self) -> int:
        return len(self._q)

    def add(self, item: Any, now: float) -> None:
        self._q.append(_Pending(item, now))

    def _expired(self, p: _Pending, now: float) -> bool:
        return (self.timeout_s is not None
                and now > p.t_enqueue + self.timeout_s)

    def deadline(self) -> Optional[float]:
        """Wall time at which the worker must next wake: the oldest pending
        request's flush deadline, or its expiry if that comes first.
        None when the queue is empty."""
        if not self._q:
            return None
        dl = self._q[0].t_enqueue + self.max_delay_s
        if self.timeout_s is not None:
            dl = min(dl, self._q[0].t_enqueue + self.timeout_s)
        return dl

    def flush_due(self, now: float) -> bool:
        """True when a batch should be emitted: ``max_batch`` waiting or the
        oldest request has waited ``max_delay_s``."""
        if not self._q:
            return False
        return (len(self._q) >= self.max_batch
                or now >= self._q[0].t_enqueue + self.max_delay_s)

    def ready(self, now: float) -> bool:
        """True when the worker has something to do — flush a batch *or*
        fail expired requests."""
        if not self._q:
            return False
        return self.flush_due(now) or self._expired(self._q[0], now)

    def pop_expired(self, now: float) -> list[_Pending]:
        """Remove and return requests whose queue timeout has passed.
        FIFO order makes enqueue times monotone, so expired requests are
        always a prefix of the queue."""
        out: list[_Pending] = []
        while self._q and self._expired(self._q[0], now):
            out.append(self._q.popleft())
        return out

    def pop_batch(self) -> list[_Pending]:
        """Pop up to ``max_batch`` oldest pending requests (possibly fewer —
        a deadline flush takes whatever is waiting)."""
        n = min(len(self._q), self.max_batch)
        return [self._q.popleft() for _ in range(n)]
