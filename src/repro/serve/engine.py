"""Batched streaming TCAM inference server.

``TCAMServer`` turns a compiled DT2CAM model into a production-style serving
engine on the Pallas kernels:

* request queue with adaptive batch formation — flush on max-batch fill or on
  the oldest request hitting its queueing deadline (``batching.py``);
* padding-bucket batching — every batch is zero-padded to a fixed ladder of
  shapes so jit recompiles are bounded by ``len(buckets) x engines``;
* warm compile cache keyed ``(bucket, engine, layout_id)`` (``cache.py``);
* engine selection ('auto'/'mxu'/'packed'/'ref') with automatic fallback to
  'mxu' when the packed engine is illegal for the layout;
* metrics — requests served, p50/p99 queue/compute/total latency, compile
  cache hits/misses, modelled nJ/decision and M decisions/s (``metrics.py``).

Chip-static non-idealities (stuck-at faults, SA V_ref offsets) are sampled
once at server construction — that is what a physical deployment looks like:
one faulty chip serving many queries.  Per-query input noise (σ_in) is drawn
per batch.

Run ``background=True`` (default) for a worker thread + Future-based
completion, or ``background=False`` for deterministic single-threaded tests
via ``pump()``/``drain()``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import threading
import time
import warnings
from concurrent.futures import Future
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.compiler import CompiledDT
from ..core.encode import encode_inputs
from ..core.energy import DEFAULT_HW, HardwareParams, f_max
from ..core.nonideal import IDEAL, NonIdealSpec, apply_saf
from ..kernels.ops import _finalize, sa_kmax, select_engine, tcam_match
from .batching import AdaptiveBatcher, BucketPolicy
from .cache import CompileCache
from .metrics import ServeMetrics

__all__ = ["ServeConfig", "RequestResult", "TCAMServer"]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Knobs of the serving engine (see module docstring)."""

    max_batch: int = 256          # flush as soon as this many are pending
    max_delay_s: float = 0.002    # oldest-request queueing deadline
    min_bucket: int = 8           # smallest padded batch shape
    engine: str = "auto"          # 'auto' | 'mxu' | 'packed' | 'ref'
    interpret: Optional[bool] = None   # Pallas interpret mode (None = auto)
    background: bool = True       # worker thread vs explicit pump()/drain()


@dataclasses.dataclass(frozen=True)
class RequestResult:
    """Per-request outcome: the decision plus its serving/modelled-hw cost."""

    prediction: int
    survivor: int                 # surviving TCAM row (-1: no match)
    n_survivors: int
    active_evals: int             # modelled active row-division evaluations
    energy_j: float               # modelled ReCAM energy for this decision
    queue_s: float                # enqueue -> batch formation
    compute_s: float              # batch dispatch -> results ready
    bucket: int                   # padded batch shape it rode in
    engine: str

    @property
    def total_s(self) -> float:
        return self.queue_s + self.compute_s


@dataclasses.dataclass
class _Request:
    x: np.ndarray
    future: Future


class TCAMServer:
    """Serve a stream of classification requests on a compiled DT2CAM model.

    >>> server = TCAMServer(model.compiled)
    >>> fut = server.submit(x_row)          # -> concurrent.futures.Future
    >>> fut.result().prediction
    >>> server.metrics()["compute_latency"]["p99_ms"]
    >>> server.close()
    """

    def __init__(
        self,
        compiled: CompiledDT,
        *,
        hw: HardwareParams = DEFAULT_HW,
        nonideal: NonIdealSpec = IDEAL,
        config: ServeConfig = ServeConfig(),
        rng: Optional[np.random.Generator] = None,
        clock: Callable[[], float] = time.perf_counter,
    ) -> None:
        self._lut = compiled.lut
        self._hw = hw
        self._config = config
        self._spec = nonideal
        self._clock = clock
        self._rng = rng or np.random.default_rng(0)

        # -- chip-static non-idealities: sampled once per server ----------
        layout = compiled.layout
        if nonideal.has_saf:
            layout = dataclasses.replace(
                layout,
                cells=apply_saf(
                    layout.cells, nonideal.p_sa0, nonideal.p_sa1, self._rng
                ),
            )
        self._layout = layout
        self._kmax: Optional[np.ndarray] = None
        if nonideal.sa_sigma > 0:
            offsets = self._rng.normal(
                0.0, nonideal.sa_sigma,
                size=(layout.cells.shape[0], layout.n_cwd),
            )
            self._kmax = sa_kmax(layout, offsets, hw)

        self.metrics_store = ServeMetrics()
        self.engine = self._resolve_engine(config.engine)

        self.policy = BucketPolicy(
            max_batch=config.max_batch, min_bucket=config.min_bucket
        )
        layout_id = hashlib.sha1(
            self._layout.cells.tobytes() + bytes([self._layout.s % 251])
        ).hexdigest()[:12]
        self.cache = CompileCache(self._build, layout_id)

        self._batcher = AdaptiveBatcher(config.max_batch, config.max_delay_s)
        self._cond = threading.Condition()
        self._outstanding = 0
        self._stop = False
        self._closed = False
        self._thread: Optional[threading.Thread] = None
        if config.background:
            self._thread = threading.Thread(
                target=self._worker, name="tcam-serve", daemon=True
            )
            self._thread.start()

    # -- engine & compile machinery ---------------------------------------
    def _resolve_engine(self, requested: str) -> str:
        try:
            return select_engine(self._layout.cells, self._layout.s, requested)
        except ValueError as e:
            if requested != "packed":
                raise
            # explicit packed on an illegal layout: serve anyway on mxu
            warnings.warn(
                f"requested engine 'packed' is illegal for this layout "
                f"({e}); falling back to 'mxu'",
                RuntimeWarning,
                stacklevel=3,
            )
            self.metrics_store.on_fallback()
            return "mxu"

    def _build(self, bucket: int, engine: str):
        """One jit'd batch function per (bucket, engine): (bucket, W) padded
        search words -> (preds, survivors, n_survivors, active_evals)."""
        layout, kmax = self._layout, self._kmax
        interpret = self._config.interpret
        classes = jnp.asarray(layout.classes)
        km = None if kmax is None else jnp.asarray(kmax)

        @jax.jit
        def run(xpad: jax.Array):
            survive, evals = tcam_match(
                layout.cells, xpad, layout.s, km,
                engine=engine, interpret=interpret,
            )
            return _finalize(survive, evals, classes)

        return run

    def warmup(self) -> int:
        """Pre-compile every bucket shape for the resolved engine so no
        request ever pays the trace+compile cost; returns #compiles."""
        before = self.cache.misses
        for b in self.policy.buckets:
            fn = self.cache.get(b, self.engine)
            w = self._layout.n_cwd * self._layout.s
            jax.block_until_ready(fn(jnp.zeros((b, w), jnp.uint8)))
        return self.cache.misses - before

    # -- request intake ----------------------------------------------------
    def submit(self, x: np.ndarray) -> Future:
        """Enqueue one feature vector; the Future resolves to a
        ``RequestResult`` once its batch has been served."""
        fut: Future = Future()
        req = _Request(np.asarray(x, np.float64), fut)
        with self._cond:
            if self._closed:
                raise RuntimeError("server is closed")
            self._batcher.add(req, self._clock())
            self._outstanding += 1
            self.metrics_store.on_enqueue()
            self._cond.notify_all()
        return fut

    def submit_many(self, X: np.ndarray) -> list[Future]:
        return [self.submit(row) for row in np.asarray(X)]

    # -- batch formation & execution ---------------------------------------
    def _worker(self) -> None:
        while True:
            with self._cond:
                now = self._clock()
                while not self._stop and not self._batcher.ready(now):
                    dl = self._batcher.deadline()
                    self._cond.wait(
                        None if dl is None else max(0.0, dl - now)
                    )
                    now = self._clock()
                if self._stop and not len(self._batcher):
                    return
                deadline_flush = len(self._batcher) < self._config.max_batch
                batch = self._batcher.pop_batch()
            if batch:
                self._process(batch, deadline_flush)

    def pump(self, *, force: bool = False) -> int:
        """Synchronous mode: process at most one due batch (``force=True``
        flushes regardless of deadline); returns #requests served."""
        with self._cond:
            now = self._clock()
            due = self._batcher.ready(now) or (force and len(self._batcher))
            if not due:
                return 0
            deadline_flush = len(self._batcher) < self._config.max_batch
            batch = self._batcher.pop_batch()
        if not batch:
            return 0
        self._process(batch, deadline_flush)
        return len(batch)

    def drain(self, timeout: Optional[float] = None) -> None:
        """Block until every submitted request has been served."""
        if self._thread is None:
            while self.pump(force=True):
                pass
            return
        with self._cond:
            if not self._cond.wait_for(
                lambda: self._outstanding == 0, timeout
            ):
                raise TimeoutError("drain timed out")

    def _process(self, batch: list, deadline_flush: bool) -> None:
        try:
            self._process_inner(batch, deadline_flush)
        except Exception as e:
            # fail the batch's futures instead of hanging drain(); the worker
            # thread survives to serve subsequent batches.
            for p in batch:
                if not p.item.future.done():
                    p.item.future.set_exception(e)
            with self._cond:
                self._outstanding -= len(batch)
                self._cond.notify_all()
            if self._thread is None:  # synchronous mode: surface to caller
                raise

    def _process_inner(self, batch: list, deadline_flush: bool) -> None:
        t_form = self._clock()
        reqs: Sequence[_Request] = [p.item for p in batch]
        queue_lat = np.array([t_form - p.t_enqueue for p in batch])
        n = len(reqs)
        bucket = self.policy.bucket_for(n)

        X = np.stack([r.x for r in reqs])
        if self._spec.sigma_in > 0:
            X = X + self._rng.normal(0.0, self._spec.sigma_in, size=X.shape)
        xbits = encode_inputs(self._lut, X)
        xpad = self._layout.pad_inputs(xbits)
        if bucket > n:
            xpad = np.pad(xpad, ((0, bucket - n), (0, 0)))

        fn = self.cache.get(bucket, self.engine)
        out = fn(jnp.asarray(xpad))
        jax.block_until_ready(out)
        compute_s = self._clock() - t_form

        preds, survivors, nsurv, active = (np.asarray(o)[:n] for o in out)
        active = active.astype(np.int64)
        energy = active.astype(np.float64) * self._hw.e_row + self._hw.e_mem

        self.metrics_store.on_batch(
            n, bucket,
            deadline_flush=deadline_flush,
            energy_j=float(energy.sum()),
            active_evals=int(active.sum()),
        )
        self.metrics_store.queue.record_many(queue_lat)
        self.metrics_store.compute.record(compute_s)
        self.metrics_store.total.record_many(queue_lat + compute_s)

        for i, req in enumerate(reqs):
            req.future.set_result(
                RequestResult(
                    prediction=int(preds[i]),
                    survivor=int(survivors[i]),
                    n_survivors=int(nsurv[i]),
                    active_evals=int(active[i]),
                    energy_j=float(energy[i]),
                    queue_s=float(queue_lat[i]),
                    compute_s=compute_s,
                    bucket=bucket,
                    engine=self.engine,
                )
            )
        with self._cond:
            self._outstanding -= n
            self._cond.notify_all()

    # -- convenience & lifecycle -------------------------------------------
    def serve(self, X: np.ndarray) -> list[RequestResult]:
        """Submit every row of X, wait for completion, return results in
        submission order."""
        futs = self.submit_many(X)
        self.drain()
        return [f.result() for f in futs]

    def metrics(self) -> dict:
        """JSON-ready snapshot: serving counters/latency + compile cache +
        modelled ReCAM hardware figures of merit."""
        lay, hw = self._layout, self._hw
        fm = f_max(lay.s, hw)
        return self.metrics_store.snapshot(
            engine=self.engine,
            buckets=list(self.policy.buckets),
            jit_cache=self.cache.stats(),
            modelled_mdecs_seq=fm / lay.n_cwd / 1e6,
            modelled_mdecs_pipe=fm / hw.pipeline_ii_cycles / 1e6,
            layout={"rows": int(lay.cells.shape[0]),
                    "width": int(lay.cells.shape[1]),
                    "s": lay.s, "n_rwd": lay.n_rwd, "n_cwd": lay.n_cwd},
        )

    def close(self) -> None:
        """Flush pending requests, stop the worker, reject new submits."""
        with self._cond:
            if self._closed:
                return
            self._closed = True
            self._stop = True
            self._cond.notify_all()
        if self._thread is not None:
            self._thread.join()
        else:
            while self.pump(force=True):
                pass

    def __enter__(self) -> "TCAMServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
