"""Batched streaming TCAM inference server.

``TCAMServer`` turns a compiled DT2CAM model into a production-style serving
engine on the Pallas kernels:

* request queue with adaptive batch formation — flush on max-batch fill or on
  the oldest request hitting its queueing deadline (``batching.py``);
* padding-bucket batching — every batch is zero-padded to a fixed ladder of
  shapes so jit recompiles are bounded by ``len(buckets) x engines``;
* warm compile cache keyed ``(bucket, engine, layout_id)`` (``cache.py``);
* engine selection ('auto'/'mxu'/'packed'/'ref') with automatic fallback to
  'mxu' when the packed engine is illegal for the layout;
* metrics — requests served, p50/p99 queue/compute/total latency, compile
  cache hits/misses, modelled nJ/dec and M dec/s (``metrics.py``).

Chip-static non-idealities (stuck-at faults, SA V_ref offsets) are sampled
once at server construction — that is what a physical deployment looks like:
one faulty chip serving many queries.  Per-query input noise (σ_in) is drawn
per batch.

Reliability layer (``repro.reliability``): the stuck-fault state is kept as
a persistent per-element ``SAFMask``, so the server can *self-test*
(march-style BIST), *repair* (remap defective rows onto write-verified spare
rows), and *canary* itself (golden vectors replayed through the compute
path).  Serving protections: bounded queue with load shedding
(``Rejected``), per-request queueing deadlines (``DeadlineExceeded``),
retry-with-backoff for transient compute failures (``ComputeFailed`` after
the budget), and a periodic canary that trips a circuit breaker driving the
degradation ladder degraded -> repair -> re-vote -> engine fallback to
'ref'.  Every submitted Future resolves — with a result or a typed error.

Temporal degradation (``repro.degradation``): with ``NonIdealSpec.drift``
set, the chip's conductances walk on a *virtual clock* (advanced per batch
via ``ServeConfig.time_per_batch_s`` or explicitly via ``advance_time``) and
the served cell grid is re-derived from the drifted readout at maintenance
epochs.  A ``ScrubScheduler`` tracks per-row write times / read counts and a
periodic maintenance pass (``scrub_every_batches``) refreshes out-of-margin
rows through the lifecycle ``WritePlan`` machinery — refresh energy lands in
the metrics and the pulses debit the (optionally shared) ``WearTracker``
endurance ledger.  The circuit-breaker ladder gains a first rung: drifted ->
scrub + refresh -> canary re-vote, before BIST+repair.

Forest mode: constructed with a ``repro.forest.CompiledForest`` the server
shards the batch path across TCAM banks — per-group batched kernels
(``kernels.banked``) pipelined via jax async dispatch, per-bank survivors
aggregated into one ensemble vote per request.  Chip health runs bank by
bank: BIST and spare-row repair per bank, survivors on remapped spare rows
translated through a physical->LUT row map back to the right vote entries,
and a bank whose repair stays degraded is disabled (drops out of the vote
and the divisor) instead of poisoning the ensemble.

Run ``background=True`` (default) for a worker thread + Future-based
completion, or ``background=False`` for deterministic single-threaded tests
via ``pump()``/``drain()``.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import threading
import time
import warnings
from concurrent.futures import Future
from typing import Callable, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..core.compiler import CompiledDT, FeatureMismatch
from ..core.encode import encode_inputs
from ..core.energy import DEFAULT_HW, HardwareParams, f_max, forest_figures
from ..core.lut import CELL_1, CELL_X
from ..core.nonideal import (
    IDEAL,
    DriftModel,
    NonIdealSpec,
    SAFMask,
    apply_saf_mask,
    sample_drift,
    sample_saf,
)
from ..degradation import ScrubPolicy, ScrubReport, ScrubScheduler, \
    layout_margins
from ..kernels.banked import tcam_match_banked
from ..kernels.ops import _finalize, sa_kmax, select_engine, tcam_match
from ..reliability.bist import BistReport, run_bist
from ..reliability.canary import CanaryProbe, CircuitBreaker, make_canary
from ..reliability.repair import RepairReport, repair_layout
from .batching import AdaptiveBatcher, BucketPolicy
from .cache import CompileCache
from .errors import ComputeFailed, DeadlineExceeded, Rejected
from .metrics import ServeMetrics

__all__ = ["PromotionReport", "RequestResult", "ServeConfig", "TCAMServer"]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Knobs of the serving engine (see module docstring)."""

    max_batch: int = 256          # flush as soon as this many are pending
    max_delay_s: float = 0.002    # oldest-request queueing deadline
    min_bucket: int = 8           # smallest padded batch shape
    engine: str = "auto"          # 'auto' | 'mxu' | 'packed' | 'ref'
    interpret: Optional[bool] = None   # Pallas interpret mode (None = auto)
    background: bool = True       # worker thread vs explicit pump()/drain()
    # -- serving protections ----------------------------------------------
    max_queue: Optional[int] = None    # admission control: shed when this
                                       # many requests are already queued
    request_timeout_s: Optional[float] = None  # per-request queue deadline
    max_retries: int = 0          # transient compute failure retry budget
    retry_backoff_s: float = 0.01      # first backoff; doubles per retry
    # -- chip-health canary / circuit breaker ------------------------------
    canary_every_batches: int = 0      # 0 disables the periodic canary
    canary_size: int = 32              # golden vectors per canary run
    canary_threshold: float = 0.9      # trip below this canary accuracy
    auto_repair: bool = True           # breaker ladder: BIST+repair first
    # -- lifecycle ----------------------------------------------------------
    compile_cache_size: Optional[int] = None  # LRU bound on compiled batch
                                              # fns (None = unbounded)
    # -- temporal degradation (drift scrub & refresh) -----------------------
    scrub_every_batches: int = 0       # 0 disables the maintenance pass
    scrub_policy: str = "margin"       # 'margin' | 'periodic'
    scrub_margin_v: float = 0.15       # refresh rows at/below this margin [V]
    scrub_period_s: float = 3600.0     # periodic policy: refresh age [s]
    scrub_max_rows: Optional[int] = None   # rows per pass (None = unbounded)
    time_per_batch_s: float = 0.0      # virtual seconds of drift per batch


@dataclasses.dataclass(frozen=True)
class RequestResult:
    """Per-request outcome: the decision plus its serving/modelled-hw cost."""

    prediction: int
    survivor: int                 # surviving TCAM row (-1: no match)
    n_survivors: int
    active_evals: int             # modelled active row-division evaluations
    energy_j: float               # modelled ReCAM energy for this decision
    queue_s: float                # enqueue -> batch formation
    compute_s: float              # batch dispatch -> results ready
    bucket: int                   # padded batch shape it rode in
    engine: str

    @property
    def total_s(self) -> float:
        return self.queue_s + self.compute_s


@dataclasses.dataclass
class _Request:
    x: np.ndarray
    future: Future
    deadline: Optional[float] = None   # absolute clock time; None = no limit


@dataclasses.dataclass(frozen=True)
class PromotionReport:
    """Outcome of one ``TCAMServer.promote()`` gate evaluation."""

    promoted: bool
    reason: str                   # 'promoted' | 'insufficient_shadow'
                                  # | 'disagreement' | 'canary'
    staged: bool                  # candidate still staged after the call
    shadow_batches: int
    shadow_requests: int
    shadow_disagreements: int
    disagreement_rate: float
    canary_accuracy: float        # NaN when the canary gate never ran

    def summary(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class _CandidateState:
    """Shadow slot: a fully-built chip state for the staged model.

    Everything the live single-model path owns — faulted layout, programmed
    intent, persistent SAF mask, SA offsets, resolved engine, its own warm
    compile cache and golden canary — so promotion is a pure attribute swap
    with no compile or sampling work inside the lock."""

    compiled: CompiledDT
    lut: object
    layout: object
    intent: np.ndarray
    ideal_cells: np.ndarray
    saf_mask: Optional[SAFMask]
    kmax: Optional[np.ndarray]
    engine: str
    cache: CompileCache
    canary: Optional[CanaryProbe]
    mirror_fraction: float
    live_batches: int = 0         # live batches seen since staging
    shadow_batches: int = 0       # of those, mirrored to the candidate
    shadow_requests: int = 0
    shadow_disagreements: int = 0
    shadow_errors: int = 0        # mirror computes that raised (live unharmed)


class TCAMServer:
    """Serve a stream of classification requests on a compiled DT2CAM model.

    >>> server = TCAMServer(model.compiled)
    >>> fut = server.submit(x_row)          # -> concurrent.futures.Future
    >>> fut.result().prediction
    >>> server.metrics()["compute_latency"]["p99_ms"]
    >>> server.close()
    """

    def __init__(
        self,
        compiled: Union[CompiledDT, "CompiledForest"],
        *,
        hw: HardwareParams = DEFAULT_HW,
        nonideal: NonIdealSpec = IDEAL,
        config: ServeConfig = ServeConfig(),
        rng: Optional[np.random.Generator] = None,
        clock: Callable[[], float] = time.perf_counter,
        wear=None,
    ) -> None:
        self._hw = hw
        self._config = config
        self._spec = nonideal
        self._clock = clock
        self._rng = rng or np.random.default_rng(0)
        self.metrics_store = ServeMetrics()
        # endurance ledger shared with the lifecycle subsystem: refresh
        # pulses and redeploy pulses debit the same per-cell counts
        self._wear = wear
        self._drift: Optional[DriftModel] = None
        self._scrub: Optional[ScrubScheduler] = None
        self._batches_since_scrub = 0

        # multi-bank (forest) mode: a CompiledForest shards the serving path
        # across banks (duck-typed to keep repro.forest an optional import)
        self._forest = compiled if hasattr(compiled, "banks") else None
        if self._forest is not None:
            if nonideal.has_drift:
                raise NotImplementedError(
                    "drift modelling is single-model only for now; model "
                    "bank drift with per-bank TCAMServer instances"
                )
            self._init_forest_state(nonideal)
        else:
            self._init_single_state(compiled, nonideal)

        self.policy = BucketPolicy(
            max_batch=config.max_batch, min_bucket=config.min_bucket
        )
        self.cache = self._make_cache()

        # -- lifecycle: shadow slot + atomic model swap --------------------
        # every batch/canary runs its whole compute under this lock, so a
        # promotion either lands before a batch (served by the new model)
        # or after it (served by the old one) — never mid-flight
        self._model_lock = threading.RLock()
        self._candidate: Optional[_CandidateState] = None
        self._prev: Optional[dict] = None   # stashed live state for rollback

        # -- chip-health machinery ----------------------------------------
        self.breaker = CircuitBreaker(threshold=config.canary_threshold)
        self._canary: Optional[CanaryProbe] = None
        n_canary = min(config.canary_size, config.max_batch)
        if n_canary > 0 and self._forest is None:
            # forest mode has no single golden layout: bank health is
            # covered by per-bank BIST/repair instead of the canary
            self._canary = make_canary(compiled.layout, n_canary, self._rng)
        self._batches_since_canary = 0
        self._repair_reports: list[RepairReport] = []
        # test/chaos seam: called with the batch's feature matrix right
        # before kernel dispatch; raising simulates a transient device fault
        # (renamed from compute_fault_hook; the old name now raises)
        self.fault_injection_hook: Optional[Callable[[np.ndarray], None]] = None

        self._batcher = AdaptiveBatcher(
            config.max_batch, config.max_delay_s,
            timeout_s=config.request_timeout_s,
        )
        self._cond = threading.Condition()
        self._outstanding = 0
        self._stop = False
        self._closed = False
        self._thread: Optional[threading.Thread] = None
        if config.background:
            self._thread = threading.Thread(
                target=self._worker, name="tcam-serve", daemon=True
            )
            self._thread.start()

    # -- per-mode chip state ------------------------------------------------
    def _init_single_state(self, compiled: CompiledDT,
                           nonideal: NonIdealSpec) -> None:
        """Single-model mode: one logical chip, sampled faults applied once.

        The SAF mask is the chip's *persistent* stuck-element state — kept
        so repair can write new row content through the same stuck cells.
        """
        self._lut = compiled.lut
        self._n_features = compiled.tree.n_features
        layout = compiled.layout
        self._intent = np.array(layout.cells, copy=True)  # programmed content
        self._saf_mask: Optional[SAFMask] = None
        if nonideal.has_saf:
            self._saf_mask = sample_saf(
                self._intent.shape, nonideal.p_sa0, nonideal.p_sa1, self._rng
            )
            faulted = apply_saf_mask(self._intent, self._saf_mask)
            # padding columns beyond decoder+LUT width are OFF-OFF (masked,
            # physically disconnected) — stuck elements there cannot reach
            # the match line, so the served grid keeps them don't-care
            faulted[:, 1 + layout.width:] = CELL_X
            layout = dataclasses.replace(layout, cells=faulted)
        self._layout = layout
        # zero-drift served layout: the grid the chip would read back right
        # after programming; under drift the live self._layout is re-derived
        # from this base at maintenance epochs
        self._base_layout = layout
        if nonideal.has_drift:
            self._drift = sample_drift(
                self._intent.shape, nonideal.drift, self._rng
            )
            if self._config.scrub_policy not in ("margin", "periodic"):
                raise ValueError(
                    f"unknown scrub_policy {self._config.scrub_policy!r}"
                )
            if self._wear is None:
                from ..lifecycle.wear import WearTracker
                self._wear = WearTracker(self._intent.shape, hw=self._hw)
            self._scrub = ScrubScheduler(
                self._intent.shape[0],
                policy=ScrubPolicy(
                    kind=self._config.scrub_policy,
                    margin_v=self._config.scrub_margin_v,
                    period_s=self._config.scrub_period_s,
                    max_rows=self._config.scrub_max_rows,
                ),
                wear=self._wear,
                hw=self._hw,
            )
        self._ideal_cells = np.array(compiled.layout.cells, copy=True)
        self._kmax: Optional[np.ndarray] = None
        if nonideal.sa_sigma > 0:
            offsets = self._rng.normal(
                0.0, nonideal.sa_sigma,
                size=(layout.cells.shape[0], layout.n_cwd),
            )
            self._kmax = sa_kmax(layout, offsets, self._hw)
        self.engine = self._resolve_engine(self._config.engine)

    def _init_forest_state(self, nonideal: NonIdealSpec) -> None:
        """Forest mode: every bank is its own physical array with its own
        sampled stuck-fault mask and SA offsets; a defective bank degrades
        the ensemble vote instead of taking down the chip."""
        forest = self._forest
        self._n_features = forest.n_features
        n = forest.n_banks
        self._f_intent = [np.array(b.layout.cells, copy=True)
                          for b in forest.banks]
        self._f_masks: list[Optional[SAFMask]] = [None] * n
        self._f_layouts = []
        for i, bank in enumerate(forest.banks):
            lay = bank.layout
            if nonideal.has_saf:
                mask = sample_saf(
                    self._f_intent[i].shape,
                    nonideal.p_sa0, nonideal.p_sa1, self._rng,
                )
                self._f_masks[i] = mask
                faulted = apply_saf_mask(self._f_intent[i], mask)
                faulted[:, 1 + lay.width:] = CELL_X
                lay = dataclasses.replace(lay, cells=faulted)
            self._f_layouts.append(lay)
        self._f_kmax_banks: list[Optional[np.ndarray]] = [None] * n
        if nonideal.sa_sigma > 0:
            for i, lay in enumerate(self._f_layouts):
                offsets = self._rng.normal(
                    0.0, nonideal.sa_sigma,
                    size=(lay.cells.shape[0], lay.n_cwd),
                )
                self._f_kmax_banks[i] = sa_kmax(lay, offsets, self._hw)
        self._f_enabled = np.ones(n, dtype=bool)
        # physical row -> LUT (vote-table) row; spares start unassigned and
        # inherit a LUT row when repair remaps a defective rule onto them
        self._f_row_map = []
        for lay in self._f_layouts:
            rm = np.full(lay.cells.shape[0], -1, dtype=np.int32)
            rm[: lay.n_rows] = np.arange(lay.n_rows, dtype=np.int32)
            self._f_row_map.append(rm)
        self._rebuild_plan()
        self.engine = self._resolve_forest_engine(self._config.engine)

    def _rebuild_plan(self) -> None:
        """(Re)shard the served (possibly faulted/repaired) bank layouts and
        splice each bank's SA-variability kmax into its group slot."""
        from ..forest.plan import plan_forest

        self._f_plan = plan_forest(self._f_layouts)
        self._f_group_kmax = []
        for grp in self._f_plan.groups:
            km = np.array(grp.kmax0, copy=True)
            for slot, bank_id in enumerate(grp.bank_ids):
                k = self._f_kmax_banks[int(bank_id)]
                if k is not None:
                    km[slot, : k.shape[0], : k.shape[1]] = k
            self._f_group_kmax.append(km)

    # -- engine & compile machinery ---------------------------------------
    def _layout_id(self, layout=None) -> str:
        if layout is None and self._forest is not None:
            return "forest-" + self._f_plan.plan_id
        lay = self._layout if layout is None else layout
        return hashlib.sha1(
            lay.cells.tobytes()
            + lay.classes.tobytes()
            + bytes([lay.s % 251])
        ).hexdigest()[:12]

    def _make_cache(self, builder=None, layout_id: Optional[str] = None
                    ) -> CompileCache:
        return CompileCache(
            builder if builder is not None else self._build,
            layout_id if layout_id is not None else self._layout_id(),
            maxsize=self._config.compile_cache_size,
        )

    def _resolve_forest_engine(self, requested: str) -> str:
        """Forest engines: 'banked' (batched einsum), 'mxu' (vmapped Pallas),
        'ref' (oracle).  'auto' means 'banked'; 'packed' is unrepresentable
        for stacked banks and falls back with a warning."""
        if requested == "auto":
            return "banked"
        if requested in ("banked", "mxu", "ref"):
            return requested
        if requested == "packed":
            warnings.warn(
                "engine 'packed' is not available in forest mode; "
                "falling back to 'banked'",
                RuntimeWarning,
                stacklevel=3,
            )
            self.metrics_store.on_fallback()
            return "banked"
        raise ValueError(
            f"unknown forest engine {requested!r}; expected 'auto', "
            "'banked', 'mxu' or 'ref'"
        )

    def _resolve_engine(self, requested: str, layout=None) -> str:
        lay = self._layout if layout is None else layout
        try:
            return select_engine(lay.cells, lay.s, requested)
        except ValueError as e:
            if requested != "packed":
                raise
            # explicit packed on an illegal layout: serve anyway on mxu
            warnings.warn(
                f"requested engine 'packed' is illegal for this layout "
                f"({e}); falling back to 'mxu'",
                RuntimeWarning,
                stacklevel=3,
            )
            self.metrics_store.on_fallback()
            return "mxu"

    def _build(self, bucket: int, engine: str):
        """One jit'd batch function per (bucket, engine): (bucket, W) padded
        search words -> (preds, survivors, n_survivors, active_evals).
        Forest mode builds one jit'd banked match per plan group instead."""
        if self._forest is not None:
            return self._build_forest(bucket, engine)
        return self._build_for(self._layout, self._kmax, bucket, engine)

    def _build_for(self, layout, kmax, bucket: int, engine: str):
        """Single-model batch function for an explicit chip state — shared
        by the live path and the staged candidate's own compile cache."""
        interpret = self._config.interpret
        classes = jnp.asarray(layout.classes)
        km = None if kmax is None else jnp.asarray(kmax)

        @jax.jit
        def run(xpad: jax.Array):
            survive, evals = tcam_match(
                layout.cells, xpad, layout.s, km,
                engine=engine, interpret=interpret,
            )
            return _finalize(survive, evals, classes)

        return run

    def _build_forest(self, bucket: int, engine: str):
        """Forest compute for one (bucket, engine): a list of jit'd banked
        match functions, one per plan group — each evaluates its whole stack
        of banks in a single kernel invocation."""
        interpret = self._config.interpret
        fns = []
        for grp, km in zip(self._f_plan.groups, self._f_group_kmax):
            run = functools.partial(
                tcam_match_banked, grp.cells, s=grp.s,
                kmax=jnp.asarray(km), engine=engine, interpret=interpret,
            )
            fns.append(jax.jit(lambda xpad, run=run: run(xpad)))
        return fns

    def warmup(self) -> int:
        """Pre-compile every bucket shape for the resolved engine so no
        request ever pays the trace+compile cost; returns #compiles."""
        before = self.cache.misses
        for b in self.policy.buckets:
            if self._forest is not None:
                fns = self.cache.get(b, self.engine)
                for grp, fn in zip(self._f_plan.groups, fns):
                    jax.block_until_ready(fn(
                        jnp.zeros((grp.n_banks, b, grp.width), jnp.uint8)
                    ))
                continue
            fn = self.cache.get(b, self.engine)
            w = self._layout.n_cwd * self._layout.s
            jax.block_until_ready(fn(jnp.zeros((b, w), jnp.uint8)))
        return self.cache.misses - before

    # -- request intake ----------------------------------------------------
    def submit(self, x: np.ndarray) -> Future:
        """Enqueue one feature vector; the Future resolves to a
        ``RequestResult`` once its batch has been served — or to a typed
        serving error (``Rejected`` on admission-control shedding,
        ``DeadlineExceeded`` on queue expiry, ``ComputeFailed`` after the
        retry budget)."""
        x = np.asarray(x, np.float64)
        if x.ndim != 1:
            raise ValueError(
                "TCAMServer.submit expects a 1-D feature vector, got shape "
                f"{x.shape}"
            )
        if x.shape[0] != self._n_features:
            raise FeatureMismatch(
                f"TCAMServer.submit: input has {x.shape[0]} features but the "
                f"served model expects {self._n_features}"
            )
        fut: Future = Future()
        now = self._clock()
        deadline = None
        if self._config.request_timeout_s is not None:
            deadline = now + self._config.request_timeout_s
        req = _Request(x, fut, deadline)
        with self._cond:
            if self._closed:
                raise RuntimeError("server is closed")
            if (self._config.max_queue is not None
                    and len(self._batcher) >= self._config.max_queue):
                self.metrics_store.on_shed()
                fut.set_exception(Rejected(
                    f"queue full ({self._config.max_queue} pending)"
                ))
                return fut
            self._batcher.add(req, now)
            self._outstanding += 1
            self.metrics_store.on_enqueue()
            self._cond.notify_all()
        return fut

    def submit_many(self, X: np.ndarray) -> list[Future]:
        return [self.submit(row) for row in np.asarray(X)]

    # -- batch formation & execution ---------------------------------------
    def _worker(self) -> None:
        while True:
            with self._cond:
                now = self._clock()
                while not self._stop and not self._batcher.ready(now):
                    dl = self._batcher.deadline()
                    self._cond.wait(
                        None if dl is None else max(0.0, dl - now)
                    )
                    now = self._clock()
                # fail queue-expired requests promptly — the batcher's
                # deadline() wakes us at first-expiry even when no flush is
                # due, so dead requests stop holding bounded-queue capacity
                expired = self._batcher.pop_expired(now)
                deadline_flush = len(self._batcher) < self._config.max_batch
                batch = (
                    self._batcher.pop_batch()
                    if (self._batcher.flush_due(now) or self._stop) else []
                )
                done = self._stop and not len(self._batcher) and not batch
            if expired:
                self._fail_expired(expired, now)
            if batch:
                self._process(batch, deadline_flush)
            if done:
                return

    def pump(self, *, force: bool = False) -> int:
        """Synchronous mode: process at most one due batch (``force=True``
        flushes regardless of deadline); returns #requests served."""
        with self._cond:
            now = self._clock()
            expired = self._batcher.pop_expired(now)
            due = (self._batcher.flush_due(now)
                   or (force and len(self._batcher)))
            deadline_flush = len(self._batcher) < self._config.max_batch
            batch = self._batcher.pop_batch() if due else []
        if expired:
            self._fail_expired(expired, now)
        if not batch:
            return 0
        n = len(batch)
        self._process(batch, deadline_flush)
        return n

    def drain(self, timeout: Optional[float] = None) -> None:
        """Block until every submitted request has been served; raises
        ``TimeoutError`` (counters intact) if it takes longer than
        ``timeout`` seconds."""
        if self._thread is None:
            while self.pump(force=True):
                pass
            return
        with self._cond:
            if not self._cond.wait_for(
                lambda: self._outstanding == 0, timeout
            ):
                raise TimeoutError("drain timed out")

    def _fail_expired(self, expired: list, now: float) -> None:
        """Resolve expired requests with ``DeadlineExceeded`` and release
        their queue accounting."""
        for p in expired:
            p.item.future.set_exception(DeadlineExceeded(
                f"request expired after {now - p.t_enqueue:.4f}s in queue"
            ))
        self.metrics_store.on_deadline_exceeded(len(expired))
        with self._cond:
            self._outstanding -= len(expired)
            self._cond.notify_all()

    def _expire_overdue(self, batch: list) -> list:
        """Safety net at process time: fail requests that expired between
        pop and dispatch; return the still-live remainder."""
        now = self._clock()
        live, expired = [], []
        for p in batch:
            req = p.item
            if req.deadline is not None and now > req.deadline:
                expired.append(p)
            else:
                live.append(p)
        if expired:
            self._fail_expired(expired, now)
        return live

    def _process(self, batch: list, deadline_flush: bool) -> None:
        batch = self._expire_overdue(batch)
        if not batch:
            return
        delay = self._config.retry_backoff_s
        attempt = 0
        while True:
            try:
                self._process_inner(batch, deadline_flush)
                break
            except Exception as e:
                if attempt < self._config.max_retries:
                    attempt += 1
                    self.metrics_store.on_retry()
                    time.sleep(delay)
                    delay *= 2
                    continue
                # retry budget exhausted: fail the batch's futures instead of
                # hanging drain(); the worker survives for subsequent batches
                self.metrics_store.on_compute_failure()
                err = ComputeFailed(
                    f"batch compute failed after {attempt + 1} attempt(s): {e!r}"
                )
                err.__cause__ = e
                for p in batch:
                    if not p.item.future.done():
                        p.item.future.set_exception(err)
                with self._cond:
                    self._outstanding -= len(batch)
                    self._cond.notify_all()
                if self._thread is None:  # synchronous mode: surface to caller
                    raise err
                break
        self._maybe_canary()
        self._maybe_scrub()

    def _process_inner(self, batch: list, deadline_flush: bool) -> None:
        with self._model_lock:
            if self._forest is not None:
                self._process_inner_forest(batch, deadline_flush)
            else:
                self._process_inner_single(batch, deadline_flush)

    def _process_inner_single(self, batch: list, deadline_flush: bool) -> None:
        t_form = self._clock()
        reqs: Sequence[_Request] = [p.item for p in batch]
        queue_lat = np.array([t_form - p.t_enqueue for p in batch])
        n = len(reqs)
        bucket = self.policy.bucket_for(n)

        X = np.stack([r.x for r in reqs])
        if self.fault_injection_hook is not None:
            self.fault_injection_hook(X)
        if self._spec.sigma_in > 0:
            X = X + self._rng.normal(0.0, self._spec.sigma_in, size=X.shape)
        xbits = encode_inputs(self._lut, X)
        xpad = self._layout.pad_inputs(xbits)
        if bucket > n:
            xpad = np.pad(xpad, ((0, bucket - n), (0, 0)))

        fn = self.cache.get(bucket, self.engine)
        out = fn(jnp.asarray(xpad))
        jax.block_until_ready(out)
        compute_s = self._clock() - t_form
        if self._scrub is not None:
            # this batch was served by the pre-advance chip state; the clock
            # ticks and the read-disturb counters accumulate afterwards
            self._scrub.advance(self._config.time_per_batch_s)
            self._scrub.note_reads(n)

        preds, survivors, nsurv, active = (np.asarray(o)[:n] for o in out)
        # shadow deployment: mirror this (post-noise) batch to the staged
        # candidate before resolving futures — a candidate-side failure must
        # not fail, retry, or double-resolve the live batch
        cand = self._candidate
        if cand is not None and self._mirror_due(cand):
            self._shadow_mirror(cand, X, bucket, preds)
        active = active.astype(np.int64)
        energy = active.astype(np.float64) * self._hw.e_row + self._hw.e_mem

        self.metrics_store.on_batch(
            n, bucket,
            deadline_flush=deadline_flush,
            energy_j=float(energy.sum()),
            active_evals=int(active.sum()),
        )
        self.metrics_store.queue.record_many(queue_lat)
        self.metrics_store.compute.record(compute_s)
        self.metrics_store.total.record_many(queue_lat + compute_s)

        for i, req in enumerate(reqs):
            req.future.set_result(
                RequestResult(
                    prediction=int(preds[i]),
                    survivor=int(survivors[i]),
                    n_survivors=int(nsurv[i]),
                    active_evals=int(active[i]),
                    energy_j=float(energy[i]),
                    queue_s=float(queue_lat[i]),
                    compute_s=compute_s,
                    bucket=bucket,
                    engine=self.engine,
                )
            )
        with self._cond:
            self._outstanding -= n
            self._cond.notify_all()

    def _process_inner_forest(self, batch: list, deadline_flush: bool) -> None:
        """Forest-mode batch: pipelined per-group compute + vote aggregation.

        Group g+1's host-side input encoding overlaps group g's device
        compute (JAX async dispatch), then per-bank survivors aggregate into
        one ensemble vote per request — disabled (defective) banks drop out
        of both the vote and the divisor."""
        from ..forest.compiler import aggregate_votes
        from ..forest.executor import encode_group

        forest = self._forest
        t_form = self._clock()
        reqs: Sequence[_Request] = [p.item for p in batch]
        queue_lat = np.array([t_form - p.t_enqueue for p in batch])
        n = len(reqs)
        bucket = self.policy.bucket_for(n)

        X = np.stack([r.x for r in reqs])
        if self.fault_injection_hook is not None:
            self.fault_injection_hook(X)
        if self._spec.sigma_in > 0:
            X = X + self._rng.normal(0.0, self._spec.sigma_in, size=X.shape)
        Xp = forest.prepare_inputs(X, who="TCAMServer")

        fns = self.cache.get(bucket, self.engine)
        pending = []
        for grp, fn in zip(self._f_plan.groups, fns):
            xpad = encode_group(forest, grp, Xp)
            if bucket > n:
                xpad = np.pad(xpad, ((0, 0), (0, bucket - n), (0, 0)))
            pending.append((grp, fn(jnp.asarray(xpad))))

        survivors = np.empty((forest.n_banks, n), np.int32)
        n_survivors = np.empty((forest.n_banks, n), np.int32)
        active = np.empty((forest.n_banks, n), np.int64)
        for grp, out in pending:
            jax.block_until_ready(out)
            survive, evals = (np.asarray(o) for o in out)
            for slot, bank_id in enumerate(grp.bank_ids):
                i = int(bank_id)
                rows_i = int(grp.rows[slot])
                sv = survive[slot, :n, :rows_i]
                ns = sv.sum(axis=1).astype(np.int32)
                first = np.argmax(sv, axis=1).astype(np.int32)
                # translate physical rows (spares after repair) to LUT rows
                rm = self._f_row_map[i]
                survivors[i] = np.where(ns > 0, rm[first], -1)
                n_survivors[i] = ns
                ev = np.minimum(evals[slot, :n, :rows_i],
                                int(grp.d_real[slot]))
                active[i] = ev.sum(axis=1).astype(np.int64)
        compute_s = self._clock() - t_form

        predictions, _score = aggregate_votes(
            forest, survivors, self._f_enabled
        )
        enabled = self._f_enabled
        n_voting = int(enabled.sum())
        active_total = active[enabled].sum(axis=0)
        energy = (active_total.astype(np.float64) * self._hw.e_row
                  + n_voting * self._hw.e_mem)

        self.metrics_store.on_batch(
            n, bucket,
            deadline_flush=deadline_flush,
            energy_j=float(energy.sum()),
            active_evals=int(active_total.sum()),
        )
        self.metrics_store.queue.record_many(queue_lat)
        self.metrics_store.compute.record(compute_s)
        self.metrics_store.total.record_many(queue_lat + compute_s)

        for i, req in enumerate(reqs):
            pred = predictions[i]
            req.future.set_result(
                RequestResult(
                    prediction=(int(pred) if np.issubdtype(
                        np.asarray(pred).dtype, np.integer) else pred),
                    survivor=-1,   # ensemble decision: no single row
                    n_survivors=int((n_survivors[enabled, i] > 0).sum()),
                    active_evals=int(active_total[i]),
                    energy_j=float(energy[i]),
                    queue_s=float(queue_lat[i]),
                    compute_s=compute_s,
                    bucket=bucket,
                    engine=self.engine,
                )
            )
        with self._cond:
            self._outstanding -= n
            self._cond.notify_all()

    # -- lifecycle: shadow deployment, promotion, rollback ------------------
    _SWAP_ATTRS = ("_lut", "_intent", "_saf_mask", "_layout", "_base_layout",
                   "_ideal_cells", "_kmax", "engine", "cache", "_canary")

    def _snapshot_model(self) -> dict:
        return {a: getattr(self, a) for a in self._SWAP_ATTRS}

    def _restore_model(self, state: dict) -> None:
        for a, v in state.items():
            setattr(self, a, v)

    @property
    def staged(self) -> bool:
        """True while a candidate model occupies the shadow slot."""
        return self._candidate is not None

    @property
    def live_intent(self) -> np.ndarray:
        """The cell content currently programmed into the chip (single-model
        mode) — the 'old' grid a lifecycle delta plan diffs against."""
        if self._forest is not None:
            raise RuntimeError(
                "live_intent is single-model only; forest intents are "
                "per-bank (see plan_forest_delta)"
            )
        return self._intent

    @property
    def live_layout(self):
        """The served (possibly faulted/repaired) layout, single-model mode."""
        if self._forest is not None:
            raise RuntimeError("live_layout is single-model only")
        return self._layout

    def stage(self, candidate: CompiledDT, *,
              mirror_fraction: float = 0.25, warm: bool = True) -> None:
        """Load a candidate model into the shadow slot.

        The candidate gets its own complete chip state on the same silicon:
        the live chip's persistent SAF mask is reused when the candidate grid
        matches its shape (a delta-reprogrammed array keeps its stuck
        elements), a fresh mask is sampled when the grid was resized.  From
        then on ``mirror_fraction`` of live batches are re-served through the
        candidate's compute path and compared prediction-for-prediction;
        ``promote()`` evaluates the gates and performs the atomic swap.

        ``warm=True`` pre-compiles every bucket shape for the candidate so
        promotion introduces no compile pause on the serving path.
        """
        if self._forest is not None or hasattr(candidate, "banks"):
            raise NotImplementedError(
                "shadow staging is single-model only; migrate forests "
                "bank-by-bank via repro.lifecycle.plan_forest_delta"
            )
        if not 0.0 < mirror_fraction <= 1.0:
            raise ValueError(
                f"mirror_fraction must be in (0, 1], got {mirror_fraction}"
            )
        if candidate.tree.n_features != self._n_features:
            raise FeatureMismatch(
                f"candidate expects {candidate.tree.n_features} features but "
                f"the live model serves {self._n_features}"
            )
        lay = candidate.layout
        intent = np.array(lay.cells, copy=True)
        mask: Optional[SAFMask] = None
        if self._spec.has_saf:
            if (self._saf_mask is not None
                    and self._saf_mask.shape == intent.shape):
                mask = self._saf_mask        # same physical array
            else:
                mask = sample_saf(
                    intent.shape, self._spec.p_sa0, self._spec.p_sa1,
                    self._rng,
                )
            faulted = apply_saf_mask(intent, mask)
            faulted[:, 1 + lay.width:] = CELL_X
            lay = dataclasses.replace(lay, cells=faulted)
        kmax: Optional[np.ndarray] = None
        if self._spec.sa_sigma > 0:
            offsets = self._rng.normal(
                0.0, self._spec.sa_sigma,
                size=(lay.cells.shape[0], lay.n_cwd),
            )
            kmax = sa_kmax(lay, offsets, self._hw)
        engine = self._resolve_engine(self._config.engine, lay)
        cache = self._make_cache(
            functools.partial(self._build_for, lay, kmax),
            self._layout_id(lay),
        )
        n_canary = min(self._config.canary_size, self._config.max_batch)
        canary = (make_canary(candidate.layout, n_canary, self._rng)
                  if n_canary > 0 else None)
        cand = _CandidateState(
            compiled=candidate, lut=candidate.lut, layout=lay, intent=intent,
            ideal_cells=np.array(candidate.layout.cells, copy=True),
            saf_mask=mask, kmax=kmax, engine=engine, cache=cache,
            canary=canary, mirror_fraction=float(mirror_fraction),
        )
        if warm:
            w = lay.n_cwd * lay.s
            for b in self.policy.buckets:
                jax.block_until_ready(
                    cache.get(b, engine)(jnp.zeros((b, w), jnp.uint8))
                )
        with self._model_lock:
            if self._candidate is not None:
                raise RuntimeError(
                    "a candidate is already staged; promote() or rollback() "
                    "it first"
                )
            self._candidate = cand
        self.metrics_store.on_stage()

    def _mirror_due(self, cand: _CandidateState) -> bool:
        """Deterministic traffic mirroring: batch i is mirrored whenever the
        running count crosses the next multiple of 1/fraction — exactly
        ``mirror_fraction`` of live batches, no RNG involved."""
        cand.live_batches += 1
        f = cand.mirror_fraction
        return int(cand.live_batches * f) > int((cand.live_batches - 1) * f)

    def _shadow_mirror(self, cand: _CandidateState, X: np.ndarray,
                       bucket: int, live_preds: np.ndarray) -> None:
        n = X.shape[0]
        try:
            xbits = encode_inputs(cand.lut, X)
            xpad = cand.layout.pad_inputs(xbits)
            if bucket > n:
                xpad = np.pad(xpad, ((0, bucket - n), (0, 0)))
            fn = cand.cache.get(bucket, cand.engine)
            preds = np.asarray(fn(jnp.asarray(xpad))[0])[:n]
        except Exception:
            cand.shadow_errors += 1
            return
        disagreements = int((preds != live_preds).sum())
        cand.shadow_batches += 1
        cand.shadow_requests += n
        cand.shadow_disagreements += disagreements
        self.metrics_store.on_shadow(n, disagreements)

    def _run_candidate_canary(self, cand: _CandidateState) -> float:
        """Candidate golden vectors through the candidate compute path."""
        if cand.canary is None:
            return float("nan")
        words = cand.canary.words
        n = len(cand.canary)
        bucket = self.policy.bucket_for(n)
        xpad = np.zeros((bucket, words.shape[1]), np.uint8)
        xpad[:n] = words
        fn = cand.cache.get(bucket, cand.engine)
        preds = np.asarray(fn(jnp.asarray(xpad))[0])[:n]
        return cand.canary.accuracy(preds)

    def promote(self, *, min_shadow_batches: int = 1,
                max_disagreement: float = 0.0) -> PromotionReport:
        """Evaluate the promotion gates; on success atomically swap the
        candidate into the live slot (the previous model is stashed for
        ``rollback()``).

        Gates, in order:

        1. shadow exposure — fewer than ``min_shadow_batches`` mirrored
           batches leaves the candidate *staged* (not an error: it simply
           has not seen enough traffic yet);
        2. disagreement — candidate-vs-live prediction drift above
           ``max_disagreement`` unstages the candidate (a retrained model
           legitimately disagrees; the operator sets the tolerance);
        3. candidate canary — the candidate's own golden vectors through its
           compute path must reach ``canary_threshold`` accuracy, else the
           candidate is unstaged (its chip state is unhealthy).

        The swap happens under the model lock: in-flight batches finish on
        the old model, later batches ride the new one, every Future resolves.
        """
        with self._model_lock:
            cand = self._candidate
            if cand is None:
                raise RuntimeError("no candidate staged; call stage() first")
            rate = (cand.shadow_disagreements / cand.shadow_requests
                    if cand.shadow_requests else 0.0)

            def report(promoted: bool, reason: str, staged: bool,
                       acc: float = float("nan")) -> PromotionReport:
                return PromotionReport(
                    promoted=promoted, reason=reason, staged=staged,
                    shadow_batches=cand.shadow_batches,
                    shadow_requests=cand.shadow_requests,
                    shadow_disagreements=cand.shadow_disagreements,
                    disagreement_rate=rate, canary_accuracy=acc,
                )

            if cand.shadow_batches < min_shadow_batches:
                return report(False, "insufficient_shadow", True)
            if rate > max_disagreement:
                self._candidate = None
                self.metrics_store.on_promotion(False)
                return report(False, "disagreement", False)
            acc = self._run_candidate_canary(cand)
            if cand.canary is not None and \
                    acc < self._config.canary_threshold:
                self._candidate = None
                self.metrics_store.on_promotion(False)
                return report(False, "canary", False, acc)

            self._prev = self._snapshot_model()
            self._lut = cand.lut
            self._intent = cand.intent
            self._saf_mask = cand.saf_mask
            self._layout = cand.layout
            self._base_layout = cand.layout
            self._ideal_cells = cand.ideal_cells
            self._kmax = cand.kmax
            self.engine = cand.engine
            self.cache = cand.cache
            self._canary = cand.canary
            self._candidate = None
            if self._scrub is not None:
                # the promotion reprogrammed the whole array: every row's
                # drift clock restarts at the freshly-written state
                self._scrub.note_write()
                self._refresh_served_layout()
            self.metrics_store.on_promotion(True)
            if cand.canary is not None:
                self.metrics_store.on_canary(
                    acc >= self._config.canary_threshold, acc
                )
                self.breaker.observe(acc)
            return report(True, "promoted", False, acc)

    def rollback(self) -> str:
        """Back out of the lifecycle: a staged candidate is unstaged
        (returns 'unstaged'); otherwise the model stashed by the last
        promotion is swapped back in (returns 'reverted')."""
        with self._model_lock:
            if self._candidate is not None:
                self._candidate = None
                self.metrics_store.on_rollback()
                return "unstaged"
            if self._prev is not None:
                self._restore_model(self._prev)
                self._prev = None
                self.metrics_store.on_rollback()
                return "reverted"
            raise RuntimeError(
                "nothing to roll back: no candidate staged and no previous "
                "model stashed"
            )

    # -- chip health: BIST, repair, canary, breaker ------------------------
    def self_test(self):
        """March-style BIST: probe every physical row of the (possibly
        faulty) array against its programmed intent; per-row defect map.
        Forest mode returns one ``BistReport`` per bank."""
        if self._forest is not None:
            return [
                run_bist(lay.cells, intent,
                         used=1 + lay.width, n_rows=lay.n_rows)
                for lay, intent in zip(self._f_layouts, self._f_intent)
            ]
        return run_bist(
            self._layout.cells, self._intent,
            used=1 + self._layout.width, n_rows=self._layout.n_rows,
        )

    def repair(
        self,
        defects=None,
        priority: Optional[np.ndarray] = None,
    ):
        """Spare-row repair: remap BIST-flagged rows onto write-verified
        spares, rebuild the compile cache, and report graceful degradation
        (``report.degraded`` when spares ran out or ghosts remain).

        Forest mode repairs bank by bank (``defects`` is the per-bank
        ``self_test()`` list) and returns one ``RepairReport`` per repaired
        bank; a bank whose repair stays degraded is *disabled* — it drops
        out of the ensemble vote instead of poisoning it."""
        if self._forest is not None:
            return self._repair_forest(defects)
        if self._saf_mask is None:
            raise RuntimeError(
                "repair requires a chip with sampled stuck-at faults "
                "(NonIdealSpec.has_saf)"
            )
        if defects is None:
            defects = self.self_test()
        # repair is a *programming* operation: it diffs and rewrites against
        # the base (zero-drift) grid.  Detection stayed honest — self_test
        # probed the drifted served grid, so retention-flipped rows can land
        # here too; the scrub rung runs first in _recover to avoid burning
        # spares on rows a refresh would have fixed.
        new_layout, new_intent, report = repair_layout(
            self._base_layout, self._intent, self._saf_mask,
            defects.defective_rows, priority=priority,
        )
        self._base_layout = new_layout
        self._layout, self._intent = new_layout, new_intent
        self._repair_reports.append(report)
        self.metrics_store.on_repair(report.rows_repaired)
        if self._scrub is not None:
            # the spares just written + the decoder-disabled originals were
            # all physically programmed: their drift clocks restart
            written = list(report.assignments.values()) + \
                list(np.asarray(report.blocked_rows).ravel())
            if written:
                self._scrub.note_write(written)
            self._refresh_served_layout(force=True)
        else:
            self._rebuild_compute()
        return report

    def _repair_forest(self, defects) -> list:
        if not any(m is not None for m in self._f_masks):
            raise RuntimeError(
                "repair requires a chip with sampled stuck-at faults "
                "(NonIdealSpec.has_saf)"
            )
        if defects is None:
            defects = self.self_test()
        reports = []
        for i, bist in enumerate(defects):
            if bist.defective_rows.size == 0 or self._f_masks[i] is None:
                continue
            new_layout, new_intent, report = repair_layout(
                self._f_layouts[i], self._f_intent[i], self._f_masks[i],
                bist.defective_rows,
            )
            self._f_layouts[i] = new_layout
            self._f_intent[i] = new_intent
            # spare rows inherit the LUT row they now carry, so post-repair
            # survivors (physical spare indices) resolve in vote-table space
            rm = self._f_row_map[i]
            for orig, spare in report.assignments.items():
                rm[int(spare)] = rm[int(orig)]
            reports.append(report)
            self.metrics_store.on_repair(report.rows_repaired)
            if report.degraded:
                self._f_enabled[i] = False
        self._repair_reports.extend(reports)
        self._rebuild_compute()
        return reports

    def disable_bank(self, bank: int) -> None:
        """Drop one bank out of the ensemble vote (degraded operation)."""
        if self._forest is None:
            raise RuntimeError("disable_bank is only valid in forest mode")
        mask = self._f_enabled.copy()
        mask[int(bank)] = False
        if not mask.any():
            raise RuntimeError("cannot disable the last voting bank")
        self._f_enabled = mask

    def _rebuild_compute(self) -> None:
        """Re-key the compile cache after the layout changed (repair) and
        re-resolve engine legality (repair writes can add/remove CELL_MM)."""
        if self._forest is not None:
            if self.engine != "ref":
                self.engine = self._resolve_forest_engine(self._config.engine)
            self._rebuild_plan()
            self.cache = self._make_cache()
            return
        if self.engine != "ref":
            self.engine = self._resolve_engine(self._config.engine)
        self.cache = self._make_cache()

    def run_canary(self) -> float:
        """Replay the golden vectors through the live compute path; returns
        canary accuracy (and records it in the metrics)."""
        with self._model_lock:
            if self._canary is None:
                raise RuntimeError("canary disabled (canary_size <= 0)")
            words = self._canary.words
            n = len(self._canary)
            bucket = self.policy.bucket_for(n)
            xpad = np.zeros((bucket, words.shape[1]), np.uint8)
            xpad[:n] = words
            fn = self.cache.get(bucket, self.engine)
            out = fn(jnp.asarray(xpad))
            preds = np.asarray(out[0])[:n]
            acc = self._canary.accuracy(preds)
        self.metrics_store.on_canary(
            acc >= self._config.canary_threshold, acc
        )
        return acc

    def _maybe_canary(self) -> None:
        if self._config.canary_every_batches <= 0 or self._canary is None:
            return
        self._batches_since_canary += 1
        if self._batches_since_canary < self._config.canary_every_batches:
            return
        self._batches_since_canary = 0
        acc = self.run_canary()
        if self.breaker.observe(acc):
            self.metrics_store.on_trip()
            self._recover()

    def _recover(self) -> None:
        """Degradation ladder: scrub drifted rows, then repair the chip,
        re-voting the canary after each rung; if still failing, fall back to
        the 'ref' engine; else mark FAILED (the server keeps answering —
        degradation stays graceful)."""
        thr = self._config.canary_threshold
        if self._scrub is not None:
            # first rung: a full refresh undoes retention/drift damage
            # without consuming spare rows — cheaper than repair when the
            # trip was temporal, a no-op-equivalent when it was stuck-at
            self.scrub_now(force=True)
            acc = self.run_canary()
            if acc >= thr:
                self.breaker.recovered("scrub", acc)
                return
        if self._config.auto_repair and self._saf_mask is not None:
            self.repair()
            acc = self.run_canary()
            if acc >= thr:
                self.breaker.recovered("repair", acc)
                return
        if self.engine != "ref":
            self.engine = "ref"
            self.cache = self._make_cache()
            acc = self.run_canary()
            if acc >= thr:
                self.breaker.recovered("fallback_ref", acc)
                return
        self.breaker.failed(self.breaker.last_accuracy)

    # -- temporal degradation: drift clock, margins, scrub passes -----------
    @property
    def drift_enabled(self) -> bool:
        """True when the chip was constructed with a drift model."""
        return self._scrub is not None

    def _require_drift(self) -> ScrubScheduler:
        if self._scrub is None:
            raise RuntimeError(
                "drift modelling disabled: construct the server with "
                "NonIdealSpec(drift=DriftSpec(...))"
            )
        return self._scrub

    def _blocked_rows(self) -> np.ndarray:
        """Decoder-disabled rows from every repair so far: they carry no
        live content, so refreshing them would waste endurance."""
        if not self._repair_reports:
            return np.zeros(0, np.int64)
        return np.unique(np.concatenate([
            np.asarray(r.blocked_rows, dtype=np.int64).ravel()
            for r in self._repair_reports
        ] + [np.zeros(0, np.int64)]))

    def _compute_margins(self):
        return layout_margins(
            self._base_layout, self._drift,
            self._scrub.ages(), self._scrub.reads, self._hw,
        )

    def _refresh_served_layout(self, *, force: bool = False) -> None:
        """Re-derive the served grid: base (programmed) layout -> drift
        readout at the rows' current stress -> stuck elements re-pinned ->
        padding columns masked.  The compile cache is only re-keyed when the
        readout grid actually changed (``force`` bypasses the comparison,
        e.g. right after a repair replaced the base layout itself)."""
        base = self._base_layout
        cells = base.cells
        if self._drift is not None and self._scrub is not None:
            cells = self._drift.readout(
                base.cells, self._scrub.ages(), self._scrub.reads, self._hw
            )
            if self._saf_mask is not None:
                cells = apply_saf_mask(cells, self._saf_mask)
            cells[:, 1 + base.width:] = CELL_X
        if not force and np.array_equal(cells, self._layout.cells):
            return
        self._layout = dataclasses.replace(base, cells=cells)
        self._rebuild_compute()

    def advance_time(self, dt: float) -> float:
        """Advance the drift virtual clock by ``dt`` seconds and re-derive
        the served grid (accelerated-aging campaigns drive this directly;
        live serving ticks it via ``ServeConfig.time_per_batch_s``).
        Returns the new virtual now."""
        with self._model_lock:
            sch = self._require_drift()
            now = sch.advance(dt)
            self._refresh_served_layout()
        return now

    def margins(self):
        """Per-row ``SenseMargins`` of the live chip at its current drift
        state (worst case over column divisions)."""
        with self._model_lock:
            self._require_drift()
            return self._compute_margins()

    def scrub_now(self, *, force: bool = False) -> ScrubReport:
        """One scrub pass: policy-selected rows (``force=True``: every
        non-blocked row) are refreshed through the lifecycle ``WritePlan``
        machinery — pulses debit the wear ledger, energy/time land in the
        metrics — and the served grid is re-derived.

        Runs under the model lock, so a pass lands entirely between batches:
        in-flight requests are never dropped or double-resolved."""
        with self._model_lock:
            sch = self._require_drift()
            base = self._base_layout
            if force:
                plan, report = sch.scrub(
                    base.cells, used=1 + base.width,
                    blocked=self._blocked_rows(),
                    force_rows=np.arange(sch.n_rows),
                )
            else:
                margins = (self._compute_margins().margin
                           if sch.policy.kind == "margin" else None)
                plan, report = sch.scrub(
                    base.cells, margins, used=1 + base.width,
                    blocked=self._blocked_rows(),
                )
            self.metrics_store.on_scrub(
                report.n_refreshed,
                report.figures["energy_j"],
                report.figures["pulses"],
            )
            self._refresh_served_layout()
        return report

    def _maybe_scrub(self) -> None:
        """Background maintenance: every ``scrub_every_batches`` processed
        batches, run one policy-driven scrub pass."""
        if self._scrub is None or self._config.scrub_every_batches <= 0:
            return
        self._batches_since_scrub += 1
        if self._batches_since_scrub < self._config.scrub_every_batches:
            return
        self._batches_since_scrub = 0
        self.scrub_now()

    def _degradation_health(self) -> dict:
        snap = self._scrub.snapshot()
        snap["margins"] = self._compute_margins().summary()
        snap["blocked_rows"] = int(self._blocked_rows().size)
        if self._wear is not None:
            snap["wear"] = self._wear.snapshot()
        return snap

    def health(self) -> dict:
        """Chip-health snapshot: breaker state, canary, spares, repairs."""
        if self._forest is not None:
            spares_total = sum(l.n_spares for l in self._f_layouts)
            spares_free = sum(
                int((intent[lay.spare_row_indices, 0] == CELL_1).sum())
                for lay, intent in zip(self._f_layouts, self._f_intent)
                if lay.n_spares
            )
            return {
                "state": self.breaker.state,
                "engine": self.engine,
                "breaker": self.breaker.snapshot(),
                "mode": "forest",
                "n_banks": self._forest.n_banks,
                "banks_enabled": int(self._f_enabled.sum()),
                "spares_total": spares_total,
                "spares_free": spares_free,
                "repair_attempts": len(self._repair_reports),
                "last_repair": (
                    self._repair_reports[-1].summary()
                    if self._repair_reports else None
                ),
            }
        spares_free = int(
            (self._intent[self._layout.spare_row_indices, 0] == CELL_1).sum()
        ) if self._layout.n_spares else 0
        return {
            "state": self.breaker.state,
            "engine": self.engine,
            "breaker": self.breaker.snapshot(),
            "candidate_staged": self._candidate is not None,
            "spares_total": self._layout.n_spares,
            "spares_free": spares_free,
            "repair_attempts": len(self._repair_reports),
            "last_repair": (
                self._repair_reports[-1].summary()
                if self._repair_reports else None
            ),
            "degradation": (
                self._degradation_health() if self._scrub is not None
                else None
            ),
        }

    # -- convenience & lifecycle -------------------------------------------
    @property
    def compute_fault_hook(self):
        """Removed — the one-release alias expired (README migration
        notes)."""
        raise AttributeError(
            "TCAMServer.compute_fault_hook was removed; use "
            "TCAMServer.fault_injection_hook instead"
        )

    @compute_fault_hook.setter
    def compute_fault_hook(self, fn) -> None:
        raise AttributeError(
            "TCAMServer.compute_fault_hook was removed; use "
            "TCAMServer.fault_injection_hook instead"
        )

    def serve(self, X: np.ndarray) -> list[RequestResult]:
        """Submit every row of X, wait for completion, return results in
        submission order."""
        futs = self.submit_many(X)
        self.drain()
        return [f.result() for f in futs]

    def metrics(self) -> dict:
        """JSON-ready snapshot: serving counters/latency + compile cache +
        chip health + modelled ReCAM hardware figures of merit."""
        if self._forest is not None:
            figs = forest_figures(self._f_layouts, self._hw)
            agg = figs["aggregate"]
            return self.metrics_store.snapshot(
                engine=self.engine,
                buckets=list(self.policy.buckets),
                jit_cache=self.cache.stats(),
                health=self.health(),
                # aggregate = raw per-bank pipelined rates summed; ensemble =
                # complete forest decisions (all banks' votes needed)
                modelled_mdecs_pipe=agg["decs_pipe"] / 1e6,
                modelled_mdecs_ensemble=agg["ensemble_decs_pipe"] / 1e6,
                forest_figures=figs,
                layout={
                    "n_banks": self._f_plan.n_banks,
                    "groups": [
                        {"banks": int(g.n_banks), "r_pad": g.r_pad,
                         "d_pad": g.d_pad, "s": g.s}
                        for g in self._f_plan.groups
                    ],
                },
            )
        lay, hw = self._layout, self._hw
        fm = f_max(lay.s, hw)
        return self.metrics_store.snapshot(
            engine=self.engine,
            buckets=list(self.policy.buckets),
            jit_cache=self.cache.stats(),
            health=self.health(),
            modelled_mdecs_seq=fm / lay.n_cwd / 1e6,
            modelled_mdecs_pipe=fm / hw.pipeline_ii_cycles / 1e6,
            layout={"rows": int(lay.cells.shape[0]),
                    "width": int(lay.cells.shape[1]),
                    "s": lay.s, "n_rwd": lay.n_rwd, "n_cwd": lay.n_cwd,
                    "spares": lay.n_spares},
        )

    def close(self) -> None:
        """Flush pending requests, stop the worker, reject new submits."""
        with self._cond:
            if self._closed:
                return
            self._closed = True
            self._stop = True
            self._cond.notify_all()
        if self._thread is not None:
            self._thread.join()
        else:
            while self.pump(force=True):
                pass

    def __enter__(self) -> "TCAMServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
