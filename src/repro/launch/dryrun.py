import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  Everything below is ordinary code.

"""Multi-pod dry-run (deliverable e).

For every (architecture × input-shape) cell this lowers + compiles the real
step function (train_step / prefill / serve_step) against ShapeDtypeStruct
stand-ins on the production meshes:

    single-pod  (16, 16)        ("data", "model")        256 chips
    multi-pod   (2, 16, 16)     ("pod", "data", "model") 512 chips

and records, per cell:
  * memory_analysis  — per-device argument/temp/output bytes (proves fit),
  * cost_analysis    — per-device HLO FLOPs & bytes accessed,
  * collective bytes — parsed from the partitioned HLO, by collective type,
into ``artifacts/dryrun/<cell>.json`` — the roofline analysis
(benchmarks/roofline.py, EXPERIMENTS.md §Roofline) is derived from these.

Usage:
  python -m repro.launch.dryrun --arch olmo_1b --shape train_4k
  python -m repro.launch.dryrun --all [--multipod-only | --singlepod-only]
"""
import argparse
import gzip
import json
import re
import time
import traceback

import jax

from ..configs import ARCHS, SHAPES, get_config, shape_cells, train_settings
from ..optim import AdamWConfig
from ..sharding import make_rules
from ..train import (
    build_decode_step, build_prefill_step, build_train_step, input_specs,
)
from .mesh import make_production_mesh

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "artifacts", "dryrun")

_COLL_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\][^=]*?"
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective-type output bytes in the partitioned module (per-chip
    shapes).  `-start/-done` async pairs are counted once (on -start)."""
    out: dict = {}
    for m in _COLL_RE.finditer(hlo_text):
        dt, dims, op = m.group(1), m.group(2), m.group(3)
        if "-done(" in m.group(0):
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out[op] = out.get(op, 0) + n * _DTYPE_BYTES.get(dt, 4)
    return out


def _step_fn_and_args(cfg, shape, rules, settings=None):
    settings = settings or {}
    specs = input_specs(cfg, shape, rules, settings)
    if shape.step == "train":
        opt_cfg = AdamWConfig(
            mu_dtype=settings.get("mu_dtype", "float32"),
            nu_dtype=settings.get("nu_dtype", "float32"))
        import jax.numpy as jnp
        fn = build_train_step(cfg, rules, opt_cfg,
                              accum=settings.get("accum", 1),
                              remat=settings.get("remat", "full"),
                              accum_dtype=jnp.dtype(
                                  settings.get("accum_dtype", "float32")))
        args = (specs["state"], specs["batch"])
    elif shape.step == "prefill":
        fn = build_prefill_step(cfg, rules)
        args = (specs["params"], specs["batch"], specs["caches"])
    else:
        fn = build_decode_step(cfg, rules)
        args = (specs["params"], specs["token"], specs["caches"],
                specs["pos"])
    return fn, args


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             donate: bool = True, save: bool = True,
             extra_tag: str = "", settings: dict | None = None) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    if settings is None:
        settings = train_settings(arch) if shape.step == "train" else {}
    if multi_pod and settings.get("accum", 1) > 1:
        # 2x the DP shards on the multi-pod mesh: halve accumulation so
        # per-shard microbatches stay integral
        settings = dict(settings, accum=max(1, settings["accum"] // 2))
    rules = make_rules(
        mesh,
        batch_divisible=(shape.global_batch %
                         (mesh.shape.get("pod", 1) * mesh.shape["data"]) == 0),
        seq_sharded_decode=(shape.step == "decode"),
        seq_parallel=settings.get("seq_parallel", False),
        dp_only=settings.get("dp_only", False),
    )
    fn, args = _step_fn_and_args(cfg, shape, rules, settings)
    t0 = time.time()
    with mesh:
        # donate the mutable state: TrainState for train, caches otherwise
        donate = {"train": (0,), "prefill": (2,), "decode": (2,)}[shape.step]
        jitted = jax.jit(fn, donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    coll = collective_bytes(compiled.as_text())

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": list(mesh.devices.shape),
        "axes": list(mesh.axis_names),
        "step": shape.step,
        "n_devices": int(mesh.devices.size),
        "memory": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
            "code_bytes": int(getattr(mem, "generated_code_size_in_bytes", 0)),
        },
        "cost": {
            "flops": float(cost.get("flops", -1)),
            "bytes_accessed": float(cost.get("bytes accessed", -1)),
        },
        "collectives": coll,
        "settings": settings,
        "t_lower_s": round(t_lower, 2),
        "t_compile_s": round(t_compile, 2),
        "params_est": cfg.n_params(),
        "params_active_est": cfg.n_active_params(),
    }
    if save:
        os.makedirs(ART_DIR, exist_ok=True)
        tag = "multipod" if multi_pod else "singlepod"
        if extra_tag:
            tag += f"_{extra_tag}"
        path = os.path.join(ART_DIR, f"{arch}__{shape_name}__{tag}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        # gzipped partitioned HLO for the loop-aware roofline analyzer
        with gzip.open(path.replace(".json", ".hlo.gz"), "wt") as f:
            f.write(compiled.as_text())
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod-only", action="store_true")
    ap.add_argument("--singlepod-only", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in ARCHS:
            for shape in shape_cells(arch):
                cells.append((arch, shape.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    meshes = [False, True]
    if args.multipod_only:
        meshes = [True]
    if args.singlepod_only:
        meshes = [False]

    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            tag = "multipod" if mp else "singlepod"
            path = os.path.join(ART_DIR, f"{arch}__{shape}__{tag}.json")
            if args.skip_existing and os.path.exists(path):
                print(f"[skip] {arch} x {shape} x {tag}")
                continue
            try:
                rec = run_cell(arch, shape, multi_pod=mp)
                gb = (rec["memory"]["argument_bytes"]
                      + rec["memory"]["temp_bytes"]) / 2**30
                print(f"[ok]   {arch} x {shape} x {tag}: "
                      f"{gb:.2f} GiB/dev, "
                      f"{rec['cost']['flops']/1e9:.1f} GFLOP/dev, "
                      f"compile {rec['t_compile_s']}s")
            except Exception as e:
                failures += 1
                print(f"[FAIL] {arch} x {shape} x {tag}: {e}")
                traceback.print_exc()
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
