"""Production meshes.

``make_production_mesh`` is a *function* (not a module-level constant) so
importing this module never touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init to obtain placeholder devices.

Topology: TPU v5e pods, 256 chips each, 16x16 (data, model) per pod;
multi-pod adds a leading "pod" axis over DCN: (2, 16, 16).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "mesh_for_devices"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_for_devices(n: int | None = None):
    """Small mesh over the actually-available devices (tests / examples):
    (data, model) with model = 1."""
    n = n or len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
