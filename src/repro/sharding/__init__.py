"""Logical-axis sharding rules (MaxText-style) for the LM substrate."""
from .rules import (
    Rules,
    current_rules,
    make_rules,
    mesh_spec,
    shard,
    use_rules,
)

__all__ = ["Rules", "current_rules", "make_rules", "mesh_spec", "shard",
           "use_rules"]
