"""Logical-axis -> mesh-axis sharding rules with divisibility fallback.

Every tensor dimension in the model substrate carries a *logical* name; a
``Rules`` object maps logical names to mesh axes and materializes
``PartitionSpec``s.  A logical dim whose size does not divide the mesh-axis
size is *replicated* (the axis is dropped) — this is what lets e.g.
phi3-medium (40 heads) compile on a 16-way tensor axis; re-enabling padded
sharding there is a recorded hillclimb (EXPERIMENTS.md §Perf).

Default mapping (1000+ node posture, see DESIGN.md §5):

  params:      vocab/heads/kv_heads/mlp/experts -> "model" (TP/EP)
               embed/ffn-in (the non-TP big dim) -> "data"  (FSDP / ZeRO-3)
  activations: batch -> ("pod", "data") (DP; pod composes as extra DP)
               heads/mlp/experts/vocab -> "model" (TP)
  decode:      cache_seq -> "model" (KV-parallel decode); for batch=1
               long-context it becomes ("data", "model") so 500k caches
               spread over all chips.

Rules are *installed* with ``use_rules`` (a context manager); model code
calls ``shard(x, *logical_dims)`` which is a no-op outside a rules context —
smoke tests on one device run the exact same model code.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["Rules", "make_rules", "mesh_spec", "shard", "use_rules",
           "current_rules"]

AxisName = Union[str, tuple, None]


@dataclasses.dataclass(frozen=True)
class Rules:
    mesh: Mesh
    table: dict  # logical name -> mesh axis (str | tuple | None)

    def axis_size(self, axis: AxisName) -> int:
        if axis is None:
            return 1
        if isinstance(axis, tuple):
            n = 1
            for a in axis:
                n *= self.mesh.shape[a]
            return n
        return self.mesh.shape[axis]

    def spec(self, logical: Sequence[Optional[str]],
             shape: Optional[Sequence[int]] = None) -> P:
        """PartitionSpec for logical dims; drops axes that don't divide the
        dim size (when ``shape`` is given) or that repeat in the spec."""
        used: set = set()
        out = []
        for i, name in enumerate(logical):
            axis = self.table.get(name) if name else None
            if axis is not None and shape is not None:
                if shape[i] % self.axis_size(axis) != 0:
                    axis = None
            # one mesh axis may appear only once in a spec
            flat = axis if isinstance(axis, tuple) else (axis,)
            if axis is not None and any(a in used for a in flat):
                axis = None
            if axis is not None:
                used.update(flat)
            out.append(axis)
        while out and out[-1] is None:
            out.pop()
        return P(*out)

    def sharding(self, logical: Sequence[Optional[str]],
                 shape: Optional[Sequence[int]] = None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical, shape))


def make_rules(
    mesh: Mesh,
    *,
    batch_divisible: bool = True,
    seq_sharded_decode: bool = False,
    seq_parallel: bool = False,
    dp_only: bool = False,
) -> Rules:
    """Build the rule table for a mesh.

    batch_divisible=False (e.g. long_500k, global batch 1): the batch axis is
    replicated and the decode cache_seq dim spreads over (data, model).
    seq_parallel=True (Megatron-SP style): activations shard their sequence
    dim over "model"; because one mesh axis appears at most once per spec,
    downstream head/mlp TP annotations dedup away automatically and weights
    are all-gathered per layer (ZeRO-3 comm pattern).
    dp_only=True (pure ZeRO-DP, the <2B-model mapping — EXPERIMENTS.md
    §Perf): the batch shards over EVERY mesh axis, activations are never
    tensor-sharded, and weights (2D-sharded at rest) are fully all-gathered
    at use.  Replaces per-layer activation-sized TP all-reduces with
    weight-sized all-gathers — a ~5x collective-bytes cut for models whose
    layers are small relative to the activation volume.
    """
    has_pod = "pod" in mesh.shape
    dp = ("pod", "data") if has_pod else ("data",)
    # dp_only batch spans (data, model) — NOT pod: the global batch (256)
    # must divide the DP degree, and pod still carries FSDP of the params
    full = ("data", "model")
    table = {
        # --- parameter dims ---
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",
        "mlp": "model",
        "experts": "model",
        "embed": dp,          # FSDP shard of the non-TP dim; multi-pod
                              # composes (pod, data) = 32-way ZeRO-3
        "embed2": None,       # second embed dim (e.g. attn out proj input)
        "head_dim": None,
        "layers": None,
        "conv": None,
        "state": None,
        # --- activation dims ---
        "act_batch": (full if dp_only else dp) if batch_divisible else None,
        "act_flat": (full if dp_only else dp) if batch_divisible else None,
        "act_seq": "model" if seq_parallel else None,
        "act_embed": None,
        "act_heads": None if dp_only else "model",
        "act_kv_heads": None if dp_only else "model",
        "act_mlp": None if dp_only else "model",
        "act_experts": None if dp_only else "model",
        "act_vocab": None if dp_only else "model",
        "act_dinner": None if dp_only else "model",
        "act_hd": None if dp_only else "model",  # decode-cache head_dim
        # --- decode cache dims ---
        # batch-divisible decode shards caches on kv_heads/head_dim (keeps
        # the per-token dynamic-update-slice shard-local); long-context
        # batch-1 decode spreads cache_seq over every axis instead.
        "cache_seq": (
            (("data", "model") if has_pod is False else ("pod", "data", "model"))
            if (seq_sharded_decode and not batch_divisible)
            else None
        ),
        # weight gather-at-use policy (see models/lm.py _gather_fsdp)
        "_gather_tp": dp_only,
    }
    # normalize tuple-of-one
    for k, v in table.items():
        if isinstance(v, tuple) and len(v) == 1:
            table[k] = v[0]
    return Rules(mesh=mesh, table=table)


def mesh_spec(rules: Rules, logical: Sequence[Optional[str]],
              shape: Optional[Sequence[int]] = None) -> P:
    return rules.spec(logical, shape)


_CTX: contextvars.ContextVar = contextvars.ContextVar("repro_rules", default=None)


def current_rules() -> Optional[Rules]:
    return _CTX.get()


@contextlib.contextmanager
def use_rules(rules: Optional[Rules]):
    tok = _CTX.set(rules)
    try:
        yield rules
    finally:
        _CTX.reset(tok)


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Annotate an activation with its logical dims; no-op without rules."""
    rules = current_rules()
    if rules is None:
        return x
    spec = rules.spec(logical, x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec)
    )
