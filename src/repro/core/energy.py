"""ReCAM analog hardware model (paper §II.C, Eqns 5-11, Tables III & IV).

All analog physics of the resistive TCAM live here: match-line RC dynamics,
dynamic range, optimal sensing time, operating frequency, per-row energy and
the area model.  The *functional* match/active-row counts are produced by the
simulator/kernels; this module converts them into Joules/seconds/m².

Calibration notes (see DESIGN.md §7): the paper's SPICE-derived constants
(E_sa, T_sa, τ_pchg, area cells) are not published.  They are calibrated here
so that the model reproduces the paper's own anchors exactly:
  * Table IV: D_cap limits {0.2,0.3,0.4,0.5,0.6} V -> max cells/row
    {154, 86, 53, 33, 21} (from Eqn 6 with Table III resistances),
  * Eqn 10: f_max = 1 GHz at S = 128,
  * Table VI: 0.098 nJ/dec on the 2000×2048 traffic LUT at S=128,
    area 0.07 mm², area/bit 0.017 µm²/bit.
"""
from __future__ import annotations

import dataclasses
import math

__all__ = ["HardwareParams", "DEFAULT_HW", "dynamic_range", "max_cells_per_row",
           "t_opt", "t_cwd", "f_max", "choose_tile_size", "TABLE_IV",
           "bank_figures", "forest_figures", "write_energy",
           "reprogram_figures", "SenseMargins", "sensing_margins",
           "mismatch_probability"]


@dataclasses.dataclass(frozen=True)
class HardwareParams:
    # --- Table III: 16nm predictive technology model ---
    r_lrs: float = 5e3         # Low Resistance State  [Ω]
    r_hrs: float = 2.5e6       # High Resistance State [Ω]
    r_on: float = 15e3         # ON  transistor        [Ω]
    r_off: float = 24.25e6     # OFF transistor        [Ω]
    c_in: float = 50e-15       # sensing capacitance   [F]
    v_dd: float = 1.0          # supply                [V]
    # --- calibrated SPICE-derived constants ---
    t_sa: float = 0.20e-9      # double-tail SA sensing time [s]
    tau_pchg: float = 0.054e-9 # precharge time constant     [s]
    t_mem: float = 1.0e-9      # 1T1R class read (parallel bits) [s]
    e_sa: float = 2.4e-15      # SA energy per evaluation    [J]
    e_tcam_eta: float = 0.90   # fraction of C·V² dissipated per active row eval
    e_mem: float = 5.0e-15     # 1T1R + SA2 class read energy [J]
    pipeline_ii_cycles: int = 3  # P/E/SA initiation interval (Fig 4) in cycles
    # --- area model cells (16nm), calibrated to Table VI ---
    a_2t2r: float = 0.0140e-12   # [m²] TCAM cell
    a_sa: float = 0.15e-12       # [m²] double-tail SA
    a_dff: float = 0.04e-12      # [m²] tag D-flipflop
    a_sp: float = 0.03e-12       # [m²] selective-precharge circuit (Fig 5)
    a_1t1r: float = 0.007e-12    # [m²] class storage cell
    a_sa2: float = 0.15e-12      # [m²] class read SA ([32])
    # --- programming (write) model: per resistive element -----------------
    # ReRAM-class constants (RETENTION's endurance lever): a SET pulse moves
    # an element HRS -> LRS, a RESET pulse LRS -> HRS; each pulse costs
    # energy, takes t_prog, and consumes one endurance cycle of the element.
    e_set: float = 1.0e-12       # SET pulse energy   [J]
    e_reset: float = 1.5e-12     # RESET pulse energy [J] (higher V/ longer)
    t_prog: float = 10.0e-9      # program pulse width [s]
    endurance_writes: float = 1.0e6  # element program cycles before failure

    # Effective 2T2R cell resistances: the searched branch in series with its
    # transistor, in parallel with the idle branch through the OFF transistor.
    @property
    def r_cell_match(self) -> float:
        return _par(self.r_hrs + self.r_on, self.r_lrs + self.r_off)

    @property
    def r_cell_mismatch(self) -> float:
        return _par(self.r_lrs + self.r_on, self.r_hrs + self.r_off)

    @property
    def e_row(self) -> float:
        """Eqn 7: E_row^active = E_TCAM + E_sa, per active row per division."""
        return self.e_tcam_eta * self.c_in * self.v_dd**2 + self.e_sa


def _par(a: float, b: float) -> float:
    return a * b / (a + b)


DEFAULT_HW = HardwareParams()


def _row_resistances(n_cells: int, hw: HardwareParams) -> tuple[float, float]:
    """(R_fm, R_1mm) for a row of n_cells: full match = n parallel matching
    cells; one-mismatch = n-1 matching ∥ 1 mismatching."""
    if n_cells < 2:
        raise ValueError("row needs >= 2 cells")
    r_fm = hw.r_cell_match / n_cells
    r_1mm = _par(hw.r_cell_match / (n_cells - 1), hw.r_cell_mismatch)
    return r_fm, r_1mm


def dynamic_range(n_cells: int, hw: HardwareParams = DEFAULT_HW) -> float:
    """Eqn 6: D_cap at t = T_opt for a row of n_cells."""
    r_fm, r_1mm = _row_resistances(n_cells, hw)
    g = r_1mm / r_fm  # γ < 1
    return hw.v_dd * g ** (g / (1.0 - g)) * (1.0 - g)


def max_cells_per_row(d_limit: float, hw: HardwareParams = DEFAULT_HW) -> int:
    """Largest row size whose dynamic range still meets d_limit (Table IV).

    D(n) is monotonically decreasing in n; the paper reports the value to the
    nearest integer of the continuous crossing, which we match by scanning and
    returning round() of the interpolated crossing.
    """
    lo, hi = 2, 4096
    if dynamic_range(hi, hw) > d_limit:
        return hi
    while hi - lo > 1:
        mid = (lo + hi) // 2
        if dynamic_range(mid, hw) >= d_limit:
            lo = mid
        else:
            hi = mid
    # interpolate the real-valued crossing between lo and hi for round-to-nearest
    d_lo, d_hi = dynamic_range(lo, hw), dynamic_range(hi, hw)
    frac = (d_lo - d_limit) / max(d_lo - d_hi, 1e-12)
    return int(round(lo + frac))


TABLE_IV = {0.2: 128, 0.3: 64, 0.4: 32, 0.5: 32, 0.6: 16}  # D_limit -> chosen S


def choose_tile_size(d_limit: float, hw: HardwareParams = DEFAULT_HW) -> int:
    """Power-of-two S not exceeding the max cells/row for d_limit (Table IV)."""
    n = max_cells_per_row(d_limit, hw)
    s = 1
    while s * 2 <= n:
        s *= 2
    return s


def t_opt(n_cells: int, hw: HardwareParams = DEFAULT_HW) -> float:
    """Eqn 8: optimal match-line sensing time for a row of n_cells."""
    r_fm, r_1mm = _row_resistances(n_cells, hw)
    return hw.c_in * math.log(r_fm / r_1mm) * (r_fm * r_1mm) / (r_fm - r_1mm)


def t_cwd(s: int, hw: HardwareParams = DEFAULT_HW) -> float:
    """Eqn 9: per-column-division latency = 3·τ_pchg + T_opt + T_sa."""
    return 3.0 * hw.tau_pchg + t_opt(s, hw) + hw.t_sa


def f_max(s: int, hw: HardwareParams = DEFAULT_HW) -> float:
    """Eqn 10: operating frequency 1 / max(T_cwd, T_mem)."""
    return 1.0 / max(t_cwd(s, hw), hw.t_mem)


# ---------------------------------------------------------------------------
# Programming (write) figures — the lifecycle subsystem's energy model
# ---------------------------------------------------------------------------

def write_energy(
    n_set: int, n_reset: int, hw: HardwareParams = DEFAULT_HW
) -> float:
    """Modelled energy [J] of a programming pass: per-element pulse counts
    times the calibrated SET/RESET pulse energies."""
    return float(n_set) * hw.e_set + float(n_reset) * hw.e_reset


def reprogram_figures(plan, hw: HardwareParams = DEFAULT_HW) -> dict:
    """Energy / time / endurance figures for one write plan.

    Duck-typed: ``plan`` needs ``kind``, ``n_cells_written``, ``n_set``,
    ``n_reset``, ``class_set``, ``class_reset`` and ``rows_touched`` (a
    ``repro.lifecycle.WritePlan``).  Pulses are modelled as serialized
    through one program driver (worst case): time = total pulses × t_prog.
    """
    n_set = int(plan.n_set) + int(plan.class_set)
    n_reset = int(plan.n_reset) + int(plan.class_reset)
    pulses = n_set + n_reset
    return {
        "kind": plan.kind,
        "cells_written": int(plan.n_cells_written),
        "rows_touched": int(plan.rows_touched),
        "set_pulses": n_set,
        "reset_pulses": n_reset,
        "pulses": pulses,
        "energy_j": write_energy(n_set, n_reset, hw),
        "time_s": pulses * hw.t_prog,
        "endurance_cycles_consumed": pulses,
    }


# ---------------------------------------------------------------------------
# Sensing-margin analysis — the degradation subsystem's detection model
# ---------------------------------------------------------------------------
# The SA references are trimmed at manufacture to the *nominal* per-division
# V_ref (midpoint of V_fm / V_1mm for ideal Table-III resistances — the same
# convention the simulator's sa_sigma model uses).  As cells drift, the
# match-line voltages move while V_ref stays fixed; the distance between them
# is the sensing margin, and a chip is due for a scrub when it shrinks.

import numpy as np  # noqa: E402  (module is otherwise numpy-free)

_erfc = np.vectorize(math.erfc, otypes=[np.float64])


@dataclasses.dataclass(frozen=True)
class SenseMargins:
    """Worst-case (over column divisions) per-row sensing margins [V].

    ``margin_match``: V_ml(full match) − V_ref — headroom before a fully
    matching row misreads as a mismatch (drifted-up LRS / drifted-down HRS
    erode it).  ``margin_mismatch``: V_ref − V_ml(worst single mismatch) —
    headroom before a one-mismatch row misreads as a match.  Either going
    negative means the row *functionally* misbehaves even with ideal SAs.
    """

    margin_match: np.ndarray      # (rows,) [V]
    margin_mismatch: np.ndarray   # (rows,) [V]
    v_ref: np.ndarray             # (n_cwd,) nominal per-division reference [V]

    @property
    def margin(self) -> np.ndarray:
        """(rows,) overall margin: min of the two failure directions."""
        return np.minimum(self.margin_match, self.margin_mismatch)

    def summary(self) -> dict:
        m = self.margin
        return {
            "min_v": float(m.min()) if m.size else float("nan"),
            "mean_v": float(m.mean()) if m.size else float("nan"),
            "rows_negative": int((m < 0).sum()),
        }


def _ml_voltage(g_row, s: int, hw: HardwareParams):
    """Match-line voltage at the sensing instant for per-row conductance
    g_row [S]: v_dd · exp(−T_opt(S) · g / C_in)  (simulate.sense_voltage
    with R_row = 1/g; reimplemented here because simulate imports energy)."""
    return hw.v_dd * np.exp(-t_opt(s, hw) * np.asarray(g_row) / hw.c_in)


def sensing_margins(
    r_match: np.ndarray,
    r_mismatch: np.ndarray,
    *,
    s: int,
    used: int,
    hw: HardwareParams = DEFAULT_HW,
    determinate: np.ndarray | None = None,
) -> SenseMargins:
    """Per-row sensing margins of a (possibly drifted) cell grid.

    ``r_match`` / ``r_mismatch`` are (rows, cols) per-cell effective
    resistances in the match / mismatch search state (e.g. from
    ``DriftModel.cell_resistances``; at zero drift every determinate cell sits
    at ``hw.r_cell_match`` / ``hw.r_cell_mismatch`` and the margins equal the
    design margins).  ``used`` = 1 + layout.width: columns at or beyond it are
    masked (OFF-OFF) and excluded, matching the simulator.  ``determinate``
    optionally masks which cells can actually mismatch (CELL_X never does);
    by default every unmasked cell is considered.
    """
    r_match = np.asarray(r_match, dtype=np.float64)
    r_mismatch = np.asarray(r_mismatch, dtype=np.float64)
    if r_match.shape != r_mismatch.shape or r_match.ndim != 2:
        raise ValueError("r_match / r_mismatch must be equal-shape 2-D grids")
    rows, cols = r_match.shape
    n_cwd = max(1, -(-cols // s))
    if determinate is None:
        determinate = np.ones((rows, cols), dtype=bool)

    m_match = np.full(rows, np.inf)
    m_mismatch = np.full(rows, np.inf)
    v_refs = np.zeros(n_cwd)
    for d in range(n_cwd):
        lo = d * s
        real = max(0, min((d + 1) * s, used, cols) - lo)
        if real == 0:
            continue
        # nominal division references (ideal resistances, n_eff = real)
        g_fm_nom = real / hw.r_cell_match
        g_1mm_nom = (real - 1) / hw.r_cell_match + 1.0 / hw.r_cell_mismatch
        v_ref = 0.5 * (_ml_voltage(g_fm_nom, s, hw)
                       + _ml_voltage(g_1mm_nom, s, hw))
        v_refs[d] = v_ref

        g_cells = 1.0 / r_match[:, lo:lo + real]          # (rows, real)
        g_fm = g_cells.sum(axis=1)                        # all cells match
        m_match = np.minimum(m_match, _ml_voltage(g_fm, s, hw) - v_ref)

        # worst single mismatch: the determinate cell whose match->mismatch
        # swap adds the LEAST conductance discharges the line the least and
        # sits closest to (or above) V_ref
        det = determinate[:, lo:lo + real]
        delta = np.where(det, 1.0 / r_mismatch[:, lo:lo + real] - g_cells,
                         np.inf)
        d_min = delta.min(axis=1)                         # inf if none can mm
        has_mm = np.isfinite(d_min)
        if has_mm.any():
            v_1mm = _ml_voltage(g_fm[has_mm] + d_min[has_mm], s, hw)
            m_mismatch[has_mm] = np.minimum(m_mismatch[has_mm], v_ref - v_1mm)

    return SenseMargins(margin_match=m_match, margin_mismatch=m_mismatch,
                        v_ref=v_refs)


def mismatch_probability(margin, sa_sigma: float) -> np.ndarray:
    """Probability that an SA with reference offset ~N(0, sa_sigma) misreads
    a row with the given sensing margin [V]: the Gaussian tail beyond the
    margin.  sa_sigma = 0 degenerates to a step (0 / ½ / 1)."""
    m = np.asarray(margin, dtype=np.float64)
    if sa_sigma < 0:
        raise ValueError(f"sa_sigma must be >= 0, got {sa_sigma}")
    if sa_sigma == 0:
        return np.where(m > 0, 0.0, np.where(m < 0, 1.0, 0.5))
    return 0.5 * _erfc(m / (sa_sigma * math.sqrt(2.0)))


# ---------------------------------------------------------------------------
# Multi-bank (forest) figures
# ---------------------------------------------------------------------------

def bank_figures(
    layout,
    hw: HardwareParams = DEFAULT_HW,
    *,
    mean_active_evals: float | None = None,
) -> dict:
    """Per-bank energy / latency / area figures for one ``TCAMLayout``.

    Duck-typed: ``layout`` only needs ``s``, ``n_cwd``, ``n_rows`` and
    ``area_m2``.  ``mean_active_evals`` (mean N_a per decision, from the
    simulator/kernels' activity trace) enables the energy-per-decision figure;
    without it the energy entry is omitted.
    """
    s, n_cwd = int(layout.s), int(layout.n_cwd)
    fm = f_max(s, hw)
    fig = {
        "s": s,
        "n_cwd": n_cwd,
        "rows": int(layout.n_rows),
        "f_max_hz": fm,
        "latency_s": n_cwd * t_cwd(s, hw) + hw.t_mem,
        "decs_seq": fm / n_cwd,
        "decs_pipe": fm / hw.pipeline_ii_cycles,
        "area_m2": float(area(hw) if callable(area := layout.area_m2) else area),
    }
    if mean_active_evals is not None:
        fig["energy_per_dec_j"] = (
            float(mean_active_evals) * hw.e_row + hw.e_mem
        )
    return fig


def forest_figures(
    layouts,
    hw: HardwareParams = DEFAULT_HW,
    *,
    mean_active_evals=None,
) -> dict:
    """Aggregate pipelined figures for a multi-bank (ensemble) deployment.

    ``layouts`` is a sequence of ``TCAMLayout``-likes (one per bank);
    ``mean_active_evals``, when given, is a matching sequence of per-bank mean
    N_a values.  Returns ``{"banks": [per-bank dicts], "aggregate": {...}}``.

    Aggregate semantics: banks run concurrently and each sustains its own
    pipelined rate, so *aggregate* dec/s is the sum over banks (raw row-match
    throughput of the chip — monotone in bank count), while the *ensemble*
    rate (complete forest decisions, which need every bank's vote) is the
    slowest bank's rate and the ensemble latency is the slowest bank's
    latency.  Area and energy per ensemble decision sum across banks.
    """
    layouts = list(layouts)
    if not layouts:
        raise ValueError("forest_figures needs at least one bank layout")
    if mean_active_evals is None:
        mean_active_evals = [None] * len(layouts)
    else:
        mean_active_evals = list(mean_active_evals)
        if len(mean_active_evals) != len(layouts):
            raise ValueError(
                f"mean_active_evals has {len(mean_active_evals)} entries for "
                f"{len(layouts)} banks"
            )
    banks = [
        bank_figures(lay, hw, mean_active_evals=ev)
        for lay, ev in zip(layouts, mean_active_evals)
    ]
    agg = {
        "n_banks": len(banks),
        "decs_pipe": sum(b["decs_pipe"] for b in banks),
        "ensemble_decs_pipe": min(b["decs_pipe"] for b in banks),
        "latency_s": max(b["latency_s"] for b in banks),
        "area_m2": sum(b["area_m2"] for b in banks),
    }
    if all("energy_per_dec_j" in b for b in banks):
        agg["energy_per_dec_j"] = sum(b["energy_per_dec_j"] for b in banks)
    return {"banks": banks, "aggregate": agg}
