"""CART decision-tree training, implemented from scratch (no sklearn offline).

Faithful to Breiman et al. CART semantics as used by the paper (§II.A.1):
binary splits of the form ``x[feature] <= threshold`` (left) / ``> threshold``
(right), greedy Gini-impurity minimisation, thresholds at midpoints between
consecutive distinct sorted feature values.  Multi-class.  Deterministic.

The tree is stored in flat arrays so it can be (a) walked by the parser and
(b) evaluated vectorised in numpy/JAX for the golden-accuracy reference.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = ["DecisionTree", "train_tree", "predict", "tree_paths", "tree_leaf_ids"]


@dataclasses.dataclass
class DecisionTree:
    """Flat-array binary decision tree.

    For node ``i``: if ``feature[i] >= 0`` it is internal, with rule
    ``x[feature[i]] <= threshold[i]`` -> go to ``left[i]`` else ``right[i]``.
    If ``feature[i] == -1`` it is a leaf predicting ``value[i]``.
    """

    feature: np.ndarray    # (nodes,) int32, -1 for leaves
    threshold: np.ndarray  # (nodes,) float64
    left: np.ndarray       # (nodes,) int32
    right: np.ndarray      # (nodes,) int32
    value: np.ndarray      # (nodes,) int32 — majority class at node
    n_features: int
    n_classes: int

    @property
    def n_nodes(self) -> int:
        return int(self.feature.shape[0])

    @property
    def n_leaves(self) -> int:
        return int(np.sum(self.feature < 0))

    def depth(self) -> int:
        def rec(i: int) -> int:
            if self.feature[i] < 0:
                return 0
            return 1 + max(rec(self.left[i]), rec(self.right[i]))

        return rec(0)


def _gini_from_counts(counts: np.ndarray, total: np.ndarray) -> np.ndarray:
    """Gini impurity 1 - sum_c p_c^2 for count rows; total may be 0 (-> 0)."""
    total = np.maximum(total, 1e-12)
    p = counts / total[..., None]
    return 1.0 - np.sum(p * p, axis=-1)


def _best_split_feature(
    x: np.ndarray, y_onehot: np.ndarray, min_leaf: int
) -> tuple[float, float]:
    """Best (gini_weighted, threshold) for one feature column. Vectorised scan.

    Returns (inf, nan) when no valid split exists.
    """
    order = np.argsort(x, kind="stable")
    xs = x[order]
    ys = y_onehot[order]
    n = xs.shape[0]
    # prefix class counts: counts_left[i] = counts of first i samples
    cum = np.cumsum(ys, axis=0)
    total = cum[-1]
    # candidate split after position i (1..n-1) where value changes
    boundary = np.nonzero(xs[1:] > xs[:-1])[0] + 1  # split sizes
    if boundary.size == 0:
        return np.inf, np.nan
    left_n = boundary.astype(np.float64)
    right_n = n - left_n
    valid = (left_n >= min_leaf) & (right_n >= min_leaf)
    if not np.any(valid):
        return np.inf, np.nan
    boundary = boundary[valid]
    left_n = left_n[valid]
    right_n = right_n[valid]
    left_counts = cum[boundary - 1]
    right_counts = total[None, :] - left_counts
    g = (
        left_n * _gini_from_counts(left_counts, left_n)
        + right_n * _gini_from_counts(right_counts, right_n)
    ) / n
    k = int(np.argmin(g))
    b = boundary[k]
    thr = 0.5 * (xs[b - 1] + xs[b])
    # Guard against midpoint rounding to an endpoint (degenerate fp case).
    if not (xs[b - 1] < thr):
        thr = xs[b - 1]
    return float(g[k]), float(thr)


def train_tree(
    X: np.ndarray,
    y: np.ndarray,
    *,
    max_depth: int = 12,
    min_samples_leaf: int = 1,
    min_samples_split: int = 2,
    max_leaves: Optional[int] = None,
) -> DecisionTree:
    """Greedy CART training (Gini).  X: (n, f) float, y: (n,) int class ids."""
    X = np.asarray(X, dtype=np.float64)
    y = np.asarray(y, dtype=np.int64)
    n, f = X.shape
    n_classes = int(y.max()) + 1 if y.size else 1
    y_onehot = np.eye(n_classes, dtype=np.float64)[y]

    feature, threshold, left, right, value = [], [], [], [], []

    def new_node() -> int:
        feature.append(-1)
        threshold.append(np.nan)
        left.append(-1)
        right.append(-1)
        value.append(0)
        return len(feature) - 1

    # each split adds exactly one eventual leaf: leaves = 1 + #splits,
    # so capping splits at max_leaves - 1 enforces the leaf budget exactly
    n_splits = [0]

    def build(idx: np.ndarray, depth: int) -> int:
        node = new_node()
        counts = y_onehot[idx].sum(axis=0)
        value[node] = int(np.argmax(counts))
        pure = counts.max() == idx.size
        budget_ok = max_leaves is None or n_splits[0] + 1 < max_leaves
        if (
            depth >= max_depth
            or idx.size < min_samples_split
            or pure
            or not budget_ok
        ):
            return node
        best_g, best_thr, best_f = np.inf, np.nan, -1
        for j in range(f):
            g, thr = _best_split_feature(X[idx, j], y_onehot[idx], min_samples_leaf)
            if g < best_g - 1e-15:
                best_g, best_thr, best_f = g, thr, j
        if best_f < 0:
            return node
        parent_g = _gini_from_counts(counts[None], np.array([idx.size]))[0]
        if best_g >= parent_g - 1e-12:  # no impurity decrease
            return node
        n_splits[0] += 1
        mask = X[idx, best_f] <= best_thr
        feature[node] = best_f
        threshold[node] = best_thr
        left[node] = build(idx[mask], depth + 1)
        right[node] = build(idx[~mask], depth + 1)
        return node

    build(np.arange(n), 0)
    return DecisionTree(
        feature=np.asarray(feature, np.int32),
        threshold=np.asarray(threshold, np.float64),
        left=np.asarray(left, np.int32),
        right=np.asarray(right, np.int32),
        value=np.asarray(value, np.int32),
        n_features=f,
        n_classes=n_classes,
    )


def predict(tree: DecisionTree, X: np.ndarray) -> np.ndarray:
    """Golden (paper: 'Python-based DT inference') vectorised prediction."""
    X = np.asarray(X, dtype=np.float64)
    node = np.zeros(X.shape[0], dtype=np.int32)
    # iterate depth times; all paths terminate at leaves (left/right = -1)
    for _ in range(max(tree.depth(), 1)):
        is_internal = tree.feature[node] >= 0
        if not np.any(is_internal):
            break
        feat = np.maximum(tree.feature[node], 0)
        go_left = X[np.arange(X.shape[0]), feat] <= tree.threshold[node]
        nxt = np.where(go_left, tree.left[node], tree.right[node])
        node = np.where(is_internal, nxt, node)
    return tree.value[node].astype(np.int32)


def tree_leaf_ids(tree: DecisionTree) -> np.ndarray:
    """Leaf node ids in the same left-to-right DFS order as ``tree_paths``.

    Row ``r`` of the reduced rule table (and hence of the encoded LUT)
    corresponds to leaf node ``tree_leaf_ids(tree)[r]`` — the hook that lets
    per-leaf side tables (e.g. ensemble class-probability storage in
    ``repro.forest``) be aligned with TCAM rows.
    """
    out: list[int] = []

    def rec(i: int) -> None:
        if tree.feature[i] < 0:
            out.append(i)
            return
        rec(int(tree.left[i]))
        rec(int(tree.right[i]))

    rec(0)
    return np.asarray(out, dtype=np.int64)


def tree_paths(tree: DecisionTree) -> list[tuple[list[tuple[int, str, float]], int]]:
    """All root->leaf paths: ([(feature, '<='|'>', threshold), ...], class).

    This is the paper's *tree parsing* step input (§II.A.2): one path per leaf,
    ordered left-to-right (deterministic).
    """
    out: list[tuple[list[tuple[int, str, float]], int]] = []

    def rec(i: int, conds: list[tuple[int, str, float]]) -> None:
        if tree.feature[i] < 0:
            out.append((list(conds), int(tree.value[i])))
            return
        f, t = int(tree.feature[i]), float(tree.threshold[i])
        conds.append((f, "<=", t))
        rec(int(tree.left[i]), conds)
        conds.pop()
        conds.append((f, ">", t))
        rec(int(tree.right[i]), conds)
        conds.pop()

    rec(0, [])
    return out
