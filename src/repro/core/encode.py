"""Ternary adaptive encoding (paper §II.A.4, Eqns 1-4, Fig 1).

Per feature i with T_i unique thresholds (from the reduced rule table), use
n_i = T_i + 1 unary bits.  Exclusive range r_k (1-indexed, k = 1..n_i) gets the
normal-form unary code with k trailing ones: r_1 -> 00..01, r_{n_i} -> 11..11.
A rule spanning exclusive ranges [LB, UB] is encoded as u_{r_LB} with the bits
where u_{r_LB} and u_{r_UB} differ replaced by don't-cares (Eqns 3-4).

Inputs are encoded with the same scheme: value v falls in exclusive range
k = 1 + #{th < v}, and is represented by that range's exact code.
"""
from __future__ import annotations

import numpy as np

from .lut import CELL_0, CELL_1, CELL_X, TernaryLUT
from .reduce import CMP_BETWEEN, CMP_GT, CMP_LE, CMP_NONE, RuleTable

__all__ = [
    "unary_code",
    "span_code",
    "feature_thresholds",
    "encode_table",
    "encode_inputs",
]


def unary_code(k: int, n: int) -> np.ndarray:
    """Normal-form unary code for exclusive range k (1-indexed) of n ranges:
    k trailing ones.  unary_code(1, 5) -> 00001, unary_code(5, 5) -> 11111."""
    if not 1 <= k <= n:
        raise ValueError(f"range index {k} out of [1, {n}]")
    code = np.zeros(n, dtype=np.int8)
    code[n - k:] = CELL_1
    return code


def span_code(lb: int, ub: int, n: int) -> np.ndarray:
    """Code for a rule spanning exclusive ranges [lb, ub] (Eqns 3-4):
    start from u_{r_lb}, write 'x' where u_{r_lb} XOR u_{r_ub} == 1."""
    if not 1 <= lb <= ub <= n:
        raise ValueError(f"bad span [{lb}, {ub}] of {n}")
    lo, hi = unary_code(lb, n), unary_code(ub, n)
    out = lo.copy()
    out[lo != hi] = CELL_X
    return out


def feature_thresholds(table: RuleTable) -> list[np.ndarray]:
    """Sorted unique thresholds per feature, T_i = |∪_j {Th1_ij, Th2_ij}|."""
    ths: list[np.ndarray] = []
    for j in range(table.n_features):
        vals = np.concatenate([table.th1[:, j], table.th2[:, j]])
        vals = np.unique(vals[np.isfinite(vals)])
        ths.append(vals)
    return ths


def _range_index(v: np.ndarray, thresholds: np.ndarray) -> np.ndarray:
    """Exclusive range index (1-based) of values v: 1 + #{th < v}.
    Range k is (th_{k-1}, th_k] with th_0=-inf, th_n=+inf."""
    if thresholds.size == 0:
        return np.ones(np.shape(v), dtype=np.int64)
    return 1 + np.searchsorted(thresholds, v, side="left").astype(np.int64)
    # side='left': count of th strictly < v is searchsorted-left for v > th
    # (v == th -> not counted -> v lands in the range it closes, inclusive ']')


def encode_table(table: RuleTable, *, nan_full_dontcare: bool = True) -> TernaryLUT:
    """Encode a reduced rule table into the ternary LUT (the DT-HW compiler's
    final step).  ``nan_full_dontcare``: encode a no-rule feature as all-x
    (paper's 'don't care' reading); if False, use the span formula over the
    full range (yields xx..x1 — functionally identical for valid inputs)."""
    ths = feature_thresholds(table)
    widths = np.array([t.size + 1 for t in ths], dtype=np.int64)  # Eqn (1)
    offsets = np.concatenate([[0], np.cumsum(widths)])
    cells = np.zeros((table.n_rows, int(offsets[-1])), dtype=np.int8)
    for r in range(table.n_rows):
        for j in range(table.n_features):
            n = int(widths[j])
            cmp_ = int(table.comparator[r, j])
            if cmp_ == CMP_NONE:
                code = (
                    np.full(n, CELL_X, dtype=np.int8)
                    if nan_full_dontcare
                    else span_code(1, n, n)
                )
            else:
                th = ths[j]
                if cmp_ == CMP_LE:
                    lb, ub = 1, 1 + int(np.searchsorted(th, table.th1[r, j], "left"))
                elif cmp_ == CMP_GT:
                    lb = 2 + int(np.searchsorted(th, table.th1[r, j], "left"))
                    ub = n
                elif cmp_ == CMP_BETWEEN:
                    lb = 2 + int(np.searchsorted(th, table.th1[r, j], "left"))
                    ub = 1 + int(np.searchsorted(th, table.th2[r, j], "left"))
                else:
                    raise ValueError(f"bad comparator {cmp_}")
                code = span_code(lb, ub, n)
            cells[r, offsets[j]: offsets[j + 1]] = code
    return TernaryLUT(
        cells=cells,
        classes=table.classes.copy(),
        n_classes=table.n_classes,
        feat_offsets=offsets,
        thresholds=ths,
    )


def encode_inputs(lut: TernaryLUT, X: np.ndarray) -> np.ndarray:
    """Encode raw feature vectors into input bit strings (batch, width) uint8.

    Each feature value maps to the exact unary code of the exclusive range it
    falls in; codes are concatenated in feature order.
    """
    X = np.asarray(X, dtype=np.float64)
    b = X.shape[0]
    out = np.zeros((b, lut.width), dtype=np.uint8)
    for j, th in enumerate(lut.thresholds):
        lo, hi = int(lut.feat_offsets[j]), int(lut.feat_offsets[j + 1])
        n = hi - lo
        k = _range_index(X[:, j], th)  # (batch,) in 1..n
        # code with k trailing ones: bit position p (0-based from left) is 1
        # iff p >= n - k
        pos = np.arange(n)[None, :]
        out[:, lo:hi] = (pos >= (n - k)[:, None]).astype(np.uint8)
    return out
