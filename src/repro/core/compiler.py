"""DT-HW compiler front door: tree -> rule table -> ternary LUT -> TCAM tiles.

``compile_tree`` performs the paper's full DT-HW pipeline (§II.A) and the
synthesizer mapping step (§II.C.1); ``DT2CAM.fit`` adds CART training so the
whole framework is one call from raw data.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .cart import DecisionTree, predict, train_tree
from .encode import encode_inputs, encode_table
from .energy import DEFAULT_HW, HardwareParams
from .lut import TernaryLUT
from .nonideal import IDEAL, NonIdealSpec, apply_saf, noisy_inputs
from .reduce import RuleTable, reduce_tree
from .simulate import SimResult, simulate
from .synth import TCAMLayout, synthesize

__all__ = [
    "CompiledDT", "compile_tree", "DT2CAM", "FeatureMismatch",
    "check_feature_count",
]

BACKENDS = ("sim", "jax")

# flat non-ideality keywords removed from DT2CAM.infer (shim expired)
_REMOVED_INFER_KWARGS = ("p_sa0", "p_sa1", "sa_sigma", "sigma_in")


class FeatureMismatch(ValueError):
    """Input feature count does not match the compiled model's.

    Raised by the inference entry points (``DT2CAM.infer``,
    ``TCAMServer.submit``, the forest executors) *before* encoding, so a
    wrong-width input fails with a clear message instead of a shape
    broadcast error deep inside ``pad_inputs``.
    """


def check_feature_count(X: np.ndarray, n_features: int, *,
                        who: str = "infer") -> np.ndarray:
    """Validate a (batch, features) matrix against the model's feature count.

    Returns ``X`` as a float64 2-D array; raises :class:`FeatureMismatch` on
    a width mismatch and ``ValueError`` on a non-2-D input.
    """
    X = np.asarray(X, dtype=np.float64)
    if X.ndim != 2:
        raise ValueError(
            f"{who} expects a 2-D (batch, features) array, got shape {X.shape}"
        )
    if X.shape[1] != n_features:
        raise FeatureMismatch(
            f"{who}: input has {X.shape[1]} features but the compiled model "
            f"expects {n_features}"
        )
    return X


@dataclasses.dataclass
class CompiledDT:
    tree: DecisionTree
    table: RuleTable
    lut: TernaryLUT
    layout: TCAMLayout

    @property
    def lut_shape(self) -> tuple[int, int]:
        """(rows, width) — the paper's 'LUT Size' column in Table V."""
        return (self.lut.n_rows, self.lut.width)


def compile_tree(
    tree: DecisionTree, s: int = 128, *, nan_full_dontcare: bool = True,
    seed: int = 0, spare_rows: int = 0,
) -> CompiledDT:
    table = reduce_tree(tree)
    lut = encode_table(table, nan_full_dontcare=nan_full_dontcare)
    layout = synthesize(lut, s, seed=seed, spare_rows=spare_rows)
    return CompiledDT(tree=tree, table=table, lut=lut, layout=layout)


class DT2CAM:
    """End-to-end framework object: fit a CART tree, compile to TCAM, infer.

    >>> m = DT2CAM(s=128).fit(X_train, y_train)
    >>> result = m.infer(X_test)                      # ideal hardware
    >>> result.accuracy(y_test) == m.golden_accuracy(X_test, y_test)
    """

    def __init__(
        self,
        s: int = 128,
        *,
        max_depth: int = 12,
        min_samples_leaf: int = 1,
        hw: HardwareParams = DEFAULT_HW,
        seed: int = 0,
        spare_rows: int = 0,
    ) -> None:
        self.s = s
        self.max_depth = max_depth
        self.min_samples_leaf = min_samples_leaf
        self.hw = hw
        self.seed = seed
        self.spare_rows = spare_rows
        self.compiled: Optional[CompiledDT] = None

    def fit(self, X: np.ndarray, y: np.ndarray) -> "DT2CAM":
        tree = train_tree(
            X, y, max_depth=self.max_depth, min_samples_leaf=self.min_samples_leaf
        )
        self.compiled = compile_tree(
            tree, self.s, seed=self.seed, spare_rows=self.spare_rows
        )
        return self

    # -- golden reference (paper: 'accuracy obtained in Python') --
    def golden_predict(self, X: np.ndarray) -> np.ndarray:
        assert self.compiled is not None, "call fit() first"
        return predict(self.compiled.tree, X)

    def golden_accuracy(self, X: np.ndarray, y: np.ndarray) -> float:
        return float((self.golden_predict(X) == np.asarray(y)).mean())

    # -- hardware-functional inference (unified front door) --
    def infer(
        self,
        X: np.ndarray,
        *,
        backend: str = "sim",
        engine: str = "auto",
        nonideal: Optional[NonIdealSpec] = None,
        selective_precharge: bool = True,
        rng: Optional[np.random.Generator] = None,
        interpret: Optional[bool] = None,
        **removed,
    ) -> SimResult:
        """Run hardware-functional inference and return a ``SimResult``.

        backend='sim' evaluates on the numpy oracle (``core.simulate``);
        backend='jax' runs the jit'd Pallas kernels (``kernels.tcam_infer``)
        — bit-identical results on ideal hardware, and identical under
        non-idealities too when seeded with the same ``rng`` (the SA-offset
        draw order matches and the kmax lowering is exact).

        engine / interpret only apply to backend='jax' ('auto' picks the
        bit-packed kernel when legal, else the MXU bitplane kernel).
        """
        if removed:
            gone = sorted(set(removed) & set(_REMOVED_INFER_KWARGS))
            if gone:
                raise TypeError(
                    f"DT2CAM.infer({', '.join(k + '=...' for k in gone)}) was "
                    "removed; pass nonideal=NonIdealSpec("
                    f"{', '.join(k + '=...' for k in gone)}) instead"
                )
            raise TypeError(
                "DT2CAM.infer() got unexpected keyword argument(s): "
                + ", ".join(sorted(removed))
            )
        assert self.compiled is not None, "call fit() first"
        if backend not in BACKENDS:
            raise ValueError(
                f"unknown backend {backend!r}; expected one of {BACKENDS}"
            )
        X = check_feature_count(
            X, self.compiled.tree.n_features, who="DT2CAM.infer"
        )
        spec = nonideal if nonideal is not None else IDEAL
        rng = rng or np.random.default_rng(self.seed)
        layout = self.compiled.layout
        if spec.has_saf:
            layout = dataclasses.replace(
                layout, cells=apply_saf(layout.cells, spec.p_sa0, spec.p_sa1, rng)
            )
        Xn = noisy_inputs(X, spec.sigma_in, rng)
        xbits = encode_inputs(self.compiled.lut, Xn)

        if backend == "sim":
            return simulate(
                layout,
                xbits,
                hw=self.hw,
                selective_precharge=selective_precharge,
                sa_sigma=spec.sa_sigma,
                rng=rng,
            )

        # backend == "jax": lazy import keeps repro.core importable without jax
        from ..kernels import sa_kmax, tcam_infer

        kmax = None
        if spec.sa_sigma > 0:
            # same draw (shape and rng position) as simulate's offsets
            offsets = rng.normal(
                0.0, spec.sa_sigma,
                size=(layout.cells.shape[0], layout.n_cwd),
            )
            kmax = sa_kmax(layout, offsets, self.hw)
        return tcam_infer(
            layout,
            xbits,
            hw=self.hw,
            kmax=kmax,
            engine=engine,
            selective_precharge=selective_precharge,
            interpret=interpret,
        )
