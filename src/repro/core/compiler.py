"""DT-HW compiler front door: tree -> rule table -> ternary LUT -> TCAM tiles.

``compile_tree`` performs the paper's full DT-HW pipeline (§II.A) and the
synthesizer mapping step (§II.C.1); ``DT2CAM.fit`` adds CART training so the
whole framework is one call from raw data.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .cart import DecisionTree, predict, train_tree
from .encode import encode_inputs, encode_table
from .energy import DEFAULT_HW, HardwareParams
from .lut import TernaryLUT
from .nonideal import apply_saf, noisy_inputs
from .reduce import RuleTable, reduce_tree
from .simulate import SimResult, simulate
from .synth import TCAMLayout, synthesize

__all__ = ["CompiledDT", "compile_tree", "DT2CAM"]


@dataclasses.dataclass
class CompiledDT:
    tree: DecisionTree
    table: RuleTable
    lut: TernaryLUT
    layout: TCAMLayout

    @property
    def lut_shape(self) -> tuple[int, int]:
        """(rows, width) — the paper's 'LUT Size' column in Table V."""
        return (self.lut.n_rows, self.lut.width)


def compile_tree(
    tree: DecisionTree, s: int = 128, *, nan_full_dontcare: bool = True,
    seed: int = 0,
) -> CompiledDT:
    table = reduce_tree(tree)
    lut = encode_table(table, nan_full_dontcare=nan_full_dontcare)
    layout = synthesize(lut, s, seed=seed)
    return CompiledDT(tree=tree, table=table, lut=lut, layout=layout)


class DT2CAM:
    """End-to-end framework object: fit a CART tree, compile to TCAM, infer.

    >>> m = DT2CAM(s=128).fit(X_train, y_train)
    >>> result = m.infer(X_test)                      # ideal hardware
    >>> result.accuracy(y_test) == m.golden_accuracy(X_test, y_test)
    """

    def __init__(
        self,
        s: int = 128,
        *,
        max_depth: int = 12,
        min_samples_leaf: int = 1,
        hw: HardwareParams = DEFAULT_HW,
        seed: int = 0,
    ) -> None:
        self.s = s
        self.max_depth = max_depth
        self.min_samples_leaf = min_samples_leaf
        self.hw = hw
        self.seed = seed
        self.compiled: Optional[CompiledDT] = None

    def fit(self, X: np.ndarray, y: np.ndarray) -> "DT2CAM":
        tree = train_tree(
            X, y, max_depth=self.max_depth, min_samples_leaf=self.min_samples_leaf
        )
        self.compiled = compile_tree(tree, self.s, seed=self.seed)
        return self

    # -- golden reference (paper: 'accuracy obtained in Python') --
    def golden_predict(self, X: np.ndarray) -> np.ndarray:
        assert self.compiled is not None, "call fit() first"
        return predict(self.compiled.tree, X)

    def golden_accuracy(self, X: np.ndarray, y: np.ndarray) -> float:
        return float((self.golden_predict(X) == np.asarray(y)).mean())

    # -- hardware-functional inference --
    def infer(
        self,
        X: np.ndarray,
        *,
        selective_precharge: bool = True,
        p_sa0: float = 0.0,
        p_sa1: float = 0.0,
        sa_sigma: float = 0.0,
        sigma_in: float = 0.0,
        rng: Optional[np.random.Generator] = None,
    ) -> SimResult:
        assert self.compiled is not None, "call fit() first"
        rng = rng or np.random.default_rng(self.seed)
        layout = self.compiled.layout
        if p_sa0 > 0 or p_sa1 > 0:
            layout = dataclasses.replace(
                layout, cells=apply_saf(layout.cells, p_sa0, p_sa1, rng)
            )
        Xn = noisy_inputs(X, sigma_in, rng)
        xbits = encode_inputs(self.compiled.lut, Xn)
        return simulate(
            layout,
            xbits,
            hw=self.hw,
            selective_precharge=selective_precharge,
            sa_sigma=sa_sigma,
            rng=rng,
        )
