"""Ternary LUT representation shared by the compiler, synthesizer and kernels.

Cell states (int8):
  CELL_0  = 0   hard 0   (2T2R {HRS, LRS})
  CELL_1  = 1   hard 1   (2T2R {LRS, HRS})
  CELL_X  = 2   don't care ({HRS, HRS}) — matches any input bit
  CELL_MM = 3   always-mismatch ({LRS, LRS}) — only arises from SA1 defects

The functional match semantics against an input *bit* b ∈ {0,1}:
  CELL_0 matches b==0; CELL_1 matches b==1; CELL_X matches both; CELL_MM none.

Bitplane form (`is0`, `is1`): mismatches(input, row) =
  Σ_bits input·is0 + (1-input)·is1 + (input + (1-input))·isMM
which is two matmuls (+ a rank-1 correction for MM cells) — the MXU-native
formulation used by the Pallas kernel (see DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["CELL_0", "CELL_1", "CELL_X", "CELL_MM", "TernaryLUT", "bitplanes"]

CELL_0 = 0
CELL_1 = 1
CELL_X = 2
CELL_MM = 3


def bitplanes(cells: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """(is0, is1) uint8 planes; a CELL_MM cell sets BOTH planes (mismatch for
    either polarity), CELL_X sets neither."""
    is0 = ((cells == CELL_0) | (cells == CELL_MM)).astype(np.uint8)
    is1 = ((cells == CELL_1) | (cells == CELL_MM)).astype(np.uint8)
    return is0, is1


@dataclasses.dataclass
class TernaryLUT:
    """Encoded decision-tree LUT (pre-tiling).

    cells:        (rows, width) int8 cell states — the TCAM rule bits only
                  (no decoder column; the synthesizer adds it).
    classes:      (rows,) int32 class label per row.
    n_classes:    number of classes C; class storage uses ceil(log2 C) bits.
    feat_offsets: (features+1,) int — bit span of feature i is
                  [feat_offsets[i], feat_offsets[i+1]).
    thresholds:   list of sorted unique threshold arrays per feature (the
                  adaptive precision sets width_i = len(thresholds[i]) + 1).
    """

    cells: np.ndarray
    classes: np.ndarray
    n_classes: int
    feat_offsets: np.ndarray
    thresholds: list[np.ndarray]

    @property
    def n_rows(self) -> int:
        return int(self.cells.shape[0])

    @property
    def width(self) -> int:
        return int(self.cells.shape[1])

    @property
    def n_total(self) -> int:
        """Paper Eqn (2): total encoded cells (rows × Σ n_i)."""
        return self.n_rows * self.width

    @property
    def class_bits(self) -> int:
        return max(1, int(np.ceil(np.log2(max(self.n_classes, 2)))))

    def class_bit_matrix(self) -> np.ndarray:
        """(rows, class_bits) uint8 binary-encoded leaf classes (paper §II.B)."""
        bits = self.class_bits
        shifts = np.arange(bits - 1, -1, -1)
        return ((self.classes[:, None] >> shifts) & 1).astype(np.uint8)
