"""Hardware non-idealities (paper §II.C.2, Table I, Fig 7/8).

Three mechanisms:
  * Stuck-At-Faults: each of the two resistive elements of a 2T2R cell
    independently sticks to HRS (SA0, prob p_sa0) or LRS (SA1, prob p_sa1).
    The resulting {R1, R2} pair maps back to a cell state, including the
    pathological {LRS, LRS} = always-mismatch (Table I).
  * SA manufacturing variability: handled inside ``simulate`` (σ_sa offsets on
    V_ref of individual sense amplifiers).
  * Input encoding noise: N(0, σ_in) added to normalized features before
    encoding.

Stuck-at faults are a *physical, persistent* property of a chip: the same
elements stay stuck no matter what is later written to the array.  The fault
state is therefore factored into an explicit ``SAFMask`` (sampled once per
chip with ``sample_saf``) that can be re-applied to any cell contents with
``apply_saf_mask`` — this is what makes spare-row repair honest: writing new
content to a row goes *through* the row's stuck elements
(``repro.reliability.repair``).  ``apply_saf`` remains the one-shot
convenience wrapper (sample + apply).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .lut import CELL_0, CELL_1, CELL_MM, CELL_X

__all__ = [
    "NonIdealSpec", "IDEAL", "SAFMask", "sample_saf", "apply_saf_mask",
    "apply_saf", "noisy_inputs", "CELL_TO_PAIR",
]


@dataclasses.dataclass(frozen=True)
class NonIdealSpec:
    """One object grouping the paper's three non-ideality mechanisms.

    Replaces the sprawling ``p_sa0/p_sa1/sa_sigma/sigma_in`` keyword lists
    that the inference entry points used to take (the flat keywords on
    ``DT2CAM.infer`` were removed after their one-release deprecation).

    p_sa0 / p_sa1: per-resistive-element stuck-at-HRS / stuck-at-LRS fault
        probabilities (Table I).
    sa_sigma: sense-amplifier V_ref manufacturing variability σ [V].
    sigma_in: input-encoding noise σ on normalized features.
    """

    p_sa0: float = 0.0
    p_sa1: float = 0.0
    sa_sigma: float = 0.0
    sigma_in: float = 0.0

    def __post_init__(self) -> None:
        for f in ("p_sa0", "p_sa1", "sa_sigma", "sigma_in"):
            if getattr(self, f) < 0:
                raise ValueError(f"{f} must be >= 0")
        if self.p_sa0 + self.p_sa1 > 1.0:
            raise ValueError("p_sa0 + p_sa1 must be <= 1")

    @property
    def is_ideal(self) -> bool:
        return (self.p_sa0 == 0 and self.p_sa1 == 0
                and self.sa_sigma == 0 and self.sigma_in == 0)

    @property
    def has_saf(self) -> bool:
        return self.p_sa0 > 0 or self.p_sa1 > 0


IDEAL = NonIdealSpec()

# cell state -> (R1 is LRS?, R2 is LRS?) — Table I encoding
CELL_TO_PAIR = {
    CELL_0: (False, True),   # {HRS, LRS}
    CELL_1: (True, False),   # {LRS, HRS}
    CELL_X: (False, False),  # {HRS, HRS}
    CELL_MM: (True, True),   # {LRS, LRS}
}
_PAIR_TO_CELL = np.zeros((2, 2), dtype=np.int8)
for _c, (_a, _b) in CELL_TO_PAIR.items():
    _PAIR_TO_CELL[int(_a), int(_b)] = _c


@dataclasses.dataclass(frozen=True)
class SAFMask:
    """Persistent per-element stuck-fault state of one physical chip.

    Four boolean arrays of the cell-grid shape; ``sa0_*`` marks elements
    stuck at HRS, ``sa1_*`` elements stuck at LRS (disjoint per element).
    """

    sa0_r1: np.ndarray
    sa1_r1: np.ndarray
    sa0_r2: np.ndarray
    sa1_r2: np.ndarray

    @property
    def shape(self) -> tuple[int, ...]:
        return self.sa0_r1.shape

    @property
    def any_fault(self) -> np.ndarray:
        """Boolean grid: cell has at least one stuck element."""
        return self.sa0_r1 | self.sa1_r1 | self.sa0_r2 | self.sa1_r2

    @property
    def n_stuck_elements(self) -> int:
        return int(self.sa0_r1.sum() + self.sa1_r1.sum()
                   + self.sa0_r2.sum() + self.sa1_r2.sum())


def _stuck_draw(
    shape: tuple[int, ...], p_sa0: float, p_sa1: float,
    rng: np.random.Generator,
) -> tuple[np.ndarray, np.ndarray]:
    """Per-element stuck state: two *independent* defect draws; when both
    fire, a fair coin picks the winner (two independent physical defects —
    whichever dominates the element is a toss-up)."""
    fire0 = rng.random(shape) < p_sa0
    fire1 = rng.random(shape) < p_sa1
    both = fire0 & fire1
    coin = rng.random(shape) < 0.5
    sa0 = (fire0 & ~fire1) | (both & coin)
    sa1 = (fire1 & ~fire0) | (both & ~coin)
    return sa0, sa1


def sample_saf(
    shape: tuple[int, ...],
    p_sa0: float,
    p_sa1: float,
    rng: np.random.Generator,
) -> SAFMask:
    """Sample one chip's persistent stuck-at fault mask.

    Each resistive element independently becomes stuck-at-HRS with prob p_sa0
    and stuck-at-LRS with prob p_sa1; if both independent defects fire on the
    same element, a 50/50 draw resolves which one dominates."""
    if p_sa0 + p_sa1 > 1.0:
        raise ValueError("p_sa0 + p_sa1 must be <= 1")
    sa0_r1, sa1_r1 = _stuck_draw(shape, p_sa0, p_sa1, rng)
    sa0_r2, sa1_r2 = _stuck_draw(shape, p_sa0, p_sa1, rng)
    return SAFMask(sa0_r1=sa0_r1, sa1_r1=sa1_r1, sa0_r2=sa0_r2, sa1_r2=sa1_r2)


def apply_saf_mask(cells: np.ndarray, mask: SAFMask) -> np.ndarray:
    """Project intended cell contents through a chip's stuck elements.

    Models a physical array write: programming pulses move every *healthy*
    element to its target state, while stuck elements keep their stuck value.
    Idempotent — re-applying the same mask is a no-op."""
    cells = np.asarray(cells)
    if mask.shape != cells.shape:
        raise ValueError(
            f"mask shape {mask.shape} != cells shape {cells.shape}"
        )
    r1_lrs = np.isin(cells, (CELL_1, CELL_MM))
    r2_lrs = np.isin(cells, (CELL_0, CELL_MM))
    r1_lrs = (r1_lrs & ~mask.sa0_r1) | mask.sa1_r1
    r2_lrs = (r2_lrs & ~mask.sa0_r2) | mask.sa1_r2
    return _PAIR_TO_CELL[r1_lrs.astype(int), r2_lrs.astype(int)]


def _require_rng(rng: Optional[np.random.Generator],
                 fn_name: str) -> np.random.Generator:
    if rng is not None:
        return rng
    # The old silent default_rng(0) fallback made every fault sweep draw the
    # same chip; the one-release deprecation shim has expired.
    raise TypeError(
        f"{fn_name}() requires an explicit rng=np.random.default_rng(seed) "
        "argument (the silent default_rng(0) fallback was removed)"
    )


def apply_saf(
    cells: np.ndarray,
    p_sa0: float,
    p_sa1: float,
    rng: Optional[np.random.Generator] = None,
) -> np.ndarray:
    """Inject stuck-at faults into a cell-state array (any shape).

    One-shot convenience: ``apply_saf_mask(cells, sample_saf(...))``.  Keep
    the ``SAFMask`` instead when the chip needs to be written again later
    (spare-row repair).

    .. versionchanged:: 0.8
       ``rng`` is required whenever faults are actually drawn; the silent
       ``default_rng(0)`` fallback was removed.
    """
    cells = np.asarray(cells)
    if p_sa0 == 0.0 and p_sa1 == 0.0:
        return cells.copy()
    rng = _require_rng(rng, "apply_saf")
    return apply_saf_mask(cells, sample_saf(cells.shape, p_sa0, p_sa1, rng))


def noisy_inputs(
    X: np.ndarray,
    sigma_in: float,
    rng: Optional[np.random.Generator] = None,
) -> np.ndarray:
    """Add input-encoding noise to (normalized) features (paper: σ_in sweep).

    .. versionchanged:: 0.8
       ``rng`` is required whenever noise is actually drawn; the silent
       ``default_rng(0)`` fallback was removed.
    """
    if sigma_in <= 0:
        return np.asarray(X, dtype=np.float64)
    rng = _require_rng(rng, "noisy_inputs")
    X = np.asarray(X, dtype=np.float64)
    return X + rng.normal(0.0, sigma_in, size=X.shape)
