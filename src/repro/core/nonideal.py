"""Hardware non-idealities (paper §II.C.2, Table I, Fig 7/8).

Static mechanisms (fixed at manufacturing / write time):
  * Stuck-At-Faults: each of the two resistive elements of a 2T2R cell
    independently sticks to HRS (SA0, prob p_sa0) or LRS (SA1, prob p_sa1).
    The resulting {R1, R2} pair maps back to a cell state, including the
    pathological {LRS, LRS} = always-mismatch (Table I).
  * SA manufacturing variability: handled inside ``simulate`` (σ_sa offsets on
    V_ref of individual sense amplifiers).
  * Input encoding noise: N(0, σ_in) added to normalized features before
    encoding.

Temporal mechanisms (grow *between* writes — Pedretti et al.'s first-order
threat to CAM-resident tree inference):
  * Conductance drift: each programmed element's resistance walks away from
    its nominal state on a log-time power law ``(1 + t/t0)^ν`` with a
    per-element exponent ν (chip-persistent, sampled once like stuck faults).
  * Retention decay: an additional exponential loss ``exp(t/τ_ret)`` that
    dominates at long horizons.
  * Read disturb: every search pulse stresses the cells; accumulated reads
    add ``read_disturb_s`` equivalent stress-seconds each, so a hot row ages
    faster than a cold one.

Both fault families are *physical, persistent* chip properties: the same
elements stay stuck (``SAFMask``) and the same elements drift fastest
(``DriftModel``) no matter what is later written.  Writing a row resets its
drift clock (that is what a scrub/refresh pulse does —
``repro.degradation``), but never its stuck elements or its drift exponents.
``apply_saf`` remains the one-shot convenience wrapper (sample + apply).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

from .lut import CELL_0, CELL_1, CELL_MM, CELL_X

__all__ = [
    "NonIdealSpec", "IDEAL", "SAFMask", "sample_saf", "apply_saf_mask",
    "apply_saf", "noisy_inputs", "CELL_TO_PAIR",
    "DriftSpec", "DriftModel", "sample_drift",
]


@dataclasses.dataclass(frozen=True)
class DriftSpec:
    """Temporal degradation law of one chip's resistive elements.

    An element programmed at time ``t_w`` and read ``k`` times since has
    accumulated equivalent stress time

        t_eff = (t - t_w) + read_disturb_s * k

    and its resistance has walked away from nominal by the factor

        f = (1 + t_eff / t0) ** ν_elem  *  exp(t_eff / retention_tau_s)

    LRS elements drift *up* (conductance loss, R *= f); HRS elements drift
    *down* (R /= f ** hrs_drift_scale — LRS retention loss dominates in
    ReRAM, so HRS drift is attenuated).  ν_elem is sampled once per element
    per chip (``sample_drift``): ``|N(nu, nu_sigma)|`` — the chip's weakest
    cells are persistent, exactly like its stuck elements.

    nu: mean log-time drift exponent (0 disables the power-law term).
    nu_sigma: per-element chip variability of the exponent.
    t0: drift-law reference time [s].
    retention_tau_s: exponential retention decay constant [s] (inf disables).
    read_disturb_s: equivalent stress seconds added per read of the element.
    hrs_drift_scale: attenuation of HRS drift relative to LRS drift.
    """

    nu: float = 0.0
    nu_sigma: float = 0.0
    t0: float = 1.0
    retention_tau_s: float = math.inf
    read_disturb_s: float = 0.0
    hrs_drift_scale: float = 0.5

    def __post_init__(self) -> None:
        for f in ("nu", "nu_sigma", "read_disturb_s", "hrs_drift_scale"):
            if getattr(self, f) < 0:
                raise ValueError(f"{f} must be >= 0")
        if self.t0 <= 0:
            raise ValueError("t0 must be > 0")
        if self.retention_tau_s <= 0:
            raise ValueError("retention_tau_s must be > 0")

    @property
    def is_ideal(self) -> bool:
        return (self.nu == 0 and self.nu_sigma == 0
                and math.isinf(self.retention_tau_s))


@dataclasses.dataclass(frozen=True)
class NonIdealSpec:
    """One object grouping the paper's three non-ideality mechanisms plus
    the temporal degradation law.

    Replaces the sprawling ``p_sa0/p_sa1/sa_sigma/sigma_in`` keyword lists
    that the inference entry points used to take (the flat keywords on
    ``DT2CAM.infer`` were removed after their one-release deprecation).

    p_sa0 / p_sa1: per-resistive-element stuck-at-HRS / stuck-at-LRS fault
        probabilities (Table I).
    sa_sigma: sense-amplifier V_ref manufacturing variability σ [V].
    sigma_in: input-encoding noise σ on normalized features.
    drift: temporal drift/retention law (``DriftSpec``); None = stable cells.
    """

    p_sa0: float = 0.0
    p_sa1: float = 0.0
    sa_sigma: float = 0.0
    sigma_in: float = 0.0
    drift: Optional[DriftSpec] = None

    def __post_init__(self) -> None:
        for f in ("p_sa0", "p_sa1", "sa_sigma", "sigma_in"):
            if getattr(self, f) < 0:
                raise ValueError(f"{f} must be >= 0")
        if self.p_sa0 + self.p_sa1 > 1.0:
            raise ValueError("p_sa0 + p_sa1 must be <= 1")
        if self.drift is not None and not isinstance(self.drift, DriftSpec):
            raise TypeError(
                f"drift must be a DriftSpec or None, got {type(self.drift)}"
            )

    @property
    def is_ideal(self) -> bool:
        return (self.p_sa0 == 0 and self.p_sa1 == 0
                and self.sa_sigma == 0 and self.sigma_in == 0
                and not self.has_drift)

    @property
    def has_saf(self) -> bool:
        return self.p_sa0 > 0 or self.p_sa1 > 0

    @property
    def has_drift(self) -> bool:
        return self.drift is not None and not self.drift.is_ideal


IDEAL = NonIdealSpec()

# cell state -> (R1 is LRS?, R2 is LRS?) — Table I encoding
CELL_TO_PAIR = {
    CELL_0: (False, True),   # {HRS, LRS}
    CELL_1: (True, False),   # {LRS, HRS}
    CELL_X: (False, False),  # {HRS, HRS}
    CELL_MM: (True, True),   # {LRS, LRS}
}
_PAIR_TO_CELL = np.zeros((2, 2), dtype=np.int8)
for _c, (_a, _b) in CELL_TO_PAIR.items():
    _PAIR_TO_CELL[int(_a), int(_b)] = _c


@dataclasses.dataclass(frozen=True)
class SAFMask:
    """Persistent per-element stuck-fault state of one physical chip.

    Four boolean arrays of the cell-grid shape; ``sa0_*`` marks elements
    stuck at HRS, ``sa1_*`` elements stuck at LRS (disjoint per element).
    """

    sa0_r1: np.ndarray
    sa1_r1: np.ndarray
    sa0_r2: np.ndarray
    sa1_r2: np.ndarray

    @property
    def shape(self) -> tuple[int, ...]:
        return self.sa0_r1.shape

    @property
    def any_fault(self) -> np.ndarray:
        """Boolean grid: cell has at least one stuck element."""
        return self.sa0_r1 | self.sa1_r1 | self.sa0_r2 | self.sa1_r2

    @property
    def n_stuck_elements(self) -> int:
        return int(self.sa0_r1.sum() + self.sa1_r1.sum()
                   + self.sa0_r2.sum() + self.sa1_r2.sum())


def _stuck_draw(
    shape: tuple[int, ...], p_sa0: float, p_sa1: float,
    rng: np.random.Generator,
) -> tuple[np.ndarray, np.ndarray]:
    """Per-element stuck state: two *independent* defect draws; when both
    fire, a fair coin picks the winner (two independent physical defects —
    whichever dominates the element is a toss-up)."""
    fire0 = rng.random(shape) < p_sa0
    fire1 = rng.random(shape) < p_sa1
    both = fire0 & fire1
    coin = rng.random(shape) < 0.5
    sa0 = (fire0 & ~fire1) | (both & coin)
    sa1 = (fire1 & ~fire0) | (both & ~coin)
    return sa0, sa1


def sample_saf(
    shape: tuple[int, ...],
    p_sa0: float,
    p_sa1: float,
    rng: np.random.Generator,
) -> SAFMask:
    """Sample one chip's persistent stuck-at fault mask.

    Each resistive element independently becomes stuck-at-HRS with prob p_sa0
    and stuck-at-LRS with prob p_sa1; if both independent defects fire on the
    same element, a 50/50 draw resolves which one dominates."""
    if p_sa0 + p_sa1 > 1.0:
        raise ValueError("p_sa0 + p_sa1 must be <= 1")
    sa0_r1, sa1_r1 = _stuck_draw(shape, p_sa0, p_sa1, rng)
    sa0_r2, sa1_r2 = _stuck_draw(shape, p_sa0, p_sa1, rng)
    return SAFMask(sa0_r1=sa0_r1, sa1_r1=sa1_r1, sa0_r2=sa0_r2, sa1_r2=sa1_r2)


def apply_saf_mask(cells: np.ndarray, mask: SAFMask) -> np.ndarray:
    """Project intended cell contents through a chip's stuck elements.

    Models a physical array write: programming pulses move every *healthy*
    element to its target state, while stuck elements keep their stuck value.
    Idempotent — re-applying the same mask is a no-op."""
    cells = np.asarray(cells)
    if mask.shape != cells.shape:
        raise ValueError(
            f"mask shape {mask.shape} != cells shape {cells.shape}"
        )
    r1_lrs = np.isin(cells, (CELL_1, CELL_MM))
    r2_lrs = np.isin(cells, (CELL_0, CELL_MM))
    r1_lrs = (r1_lrs & ~mask.sa0_r1) | mask.sa1_r1
    r2_lrs = (r2_lrs & ~mask.sa0_r2) | mask.sa1_r2
    return _PAIR_TO_CELL[r1_lrs.astype(int), r2_lrs.astype(int)]


def _require_rng(rng: Optional[np.random.Generator],
                 fn_name: str) -> np.random.Generator:
    if rng is not None:
        return rng
    # The old silent default_rng(0) fallback made every fault sweep draw the
    # same chip; the one-release deprecation shim has expired.
    raise TypeError(
        f"{fn_name}() requires an explicit rng=np.random.default_rng(seed) "
        "argument (the silent default_rng(0) fallback was removed)"
    )


def apply_saf(
    cells: np.ndarray,
    p_sa0: float,
    p_sa1: float,
    rng: Optional[np.random.Generator] = None,
) -> np.ndarray:
    """Inject stuck-at faults into a cell-state array (any shape).

    One-shot convenience: ``apply_saf_mask(cells, sample_saf(...))``.  Keep
    the ``SAFMask`` instead when the chip needs to be written again later
    (spare-row repair).

    .. versionchanged:: 0.8
       ``rng`` is required whenever faults are actually drawn; the silent
       ``default_rng(0)`` fallback was removed.
    """
    cells = np.asarray(cells)
    if p_sa0 == 0.0 and p_sa1 == 0.0:
        return cells.copy()
    rng = _require_rng(rng, "apply_saf")
    return apply_saf_mask(cells, sample_saf(cells.shape, p_sa0, p_sa1, rng))


# ---------------------------------------------------------------------------
# Temporal degradation: conductance drift / retention / read disturb
# ---------------------------------------------------------------------------

def _per_row(x, n_rows: int) -> np.ndarray:
    """Broadcast a scalar or (rows,) vector to a (rows, 1) column for
    element-grid arithmetic."""
    a = np.asarray(x, dtype=np.float64)
    if a.ndim == 0:
        return np.full((n_rows, 1), float(a))
    if a.shape != (n_rows,):
        raise ValueError(
            f"per-row quantity has shape {a.shape}, expected ({n_rows},) "
            "or a scalar"
        )
    return a[:, None]


@dataclasses.dataclass(frozen=True)
class DriftModel:
    """Persistent per-element drift state of one physical chip.

    Two exponent grids of the cell-grid shape (one per resistive element),
    sampled once per chip with ``sample_drift`` — the chip's fast-drifting
    elements stay its fast-drifting elements across rewrites; only the
    *stress clock* resets when a row is (re)programmed.

    ``t_since_write`` / ``reads_since_write`` arguments are per-row (the
    write/refresh granularity) — scalars or (rows,) vectors.
    """

    spec: DriftSpec
    nu_r1: np.ndarray
    nu_r2: np.ndarray

    @property
    def shape(self) -> tuple[int, ...]:
        return self.nu_r1.shape

    def stress_time(self, t_since_write, reads_since_write,
                    n_rows: Optional[int] = None) -> np.ndarray:
        """(rows, 1) equivalent stress time: wall age + read-disturb
        contribution (each read adds ``read_disturb_s`` stress seconds)."""
        rows = self.shape[0] if n_rows is None else n_rows
        t = _per_row(t_since_write, rows)
        k = _per_row(reads_since_write, rows)
        return np.maximum(t + self.spec.read_disturb_s * k, 0.0)

    def growth(self, t_since_write, reads_since_write) -> tuple[np.ndarray,
                                                                np.ndarray]:
        """Per-element resistance walk factors (>= 1), one grid per element:
        ``(1 + t_eff/t0)^ν * exp(t_eff/τ_ret)``."""
        t_eff = self.stress_time(t_since_write, reads_since_write)
        base = 1.0 + t_eff / self.spec.t0
        ret = (np.exp(t_eff / self.spec.retention_tau_s)
               if math.isfinite(self.spec.retention_tau_s) else 1.0)
        return base ** self.nu_r1 * ret, base ** self.nu_r2 * ret

    def resistances(
        self, cells: np.ndarray, t_since_write, reads_since_write,
        hw=None,
    ) -> tuple[np.ndarray, np.ndarray]:
        """Effective per-element resistances (R1, R2) of the programmed
        grid after drift: LRS elements drift up by f, HRS elements down by
        ``f ** hrs_drift_scale``."""
        hw = hw or _default_hw()
        cells = np.asarray(cells)
        if cells.shape != self.shape:
            raise ValueError(
                f"cells shape {cells.shape} != drift grid {self.shape}"
            )
        f1, f2 = self.growth(t_since_write, reads_since_write)
        r1_lrs = np.isin(cells, (CELL_1, CELL_MM))
        r2_lrs = np.isin(cells, (CELL_0, CELL_MM))
        g = self.spec.hrs_drift_scale
        r1 = np.where(r1_lrs, hw.r_lrs * f1, hw.r_hrs / f1 ** g)
        r2 = np.where(r2_lrs, hw.r_lrs * f2, hw.r_hrs / f2 ** g)
        return r1, r2

    def readout(
        self, cells: np.ndarray, t_since_write, reads_since_write,
        hw=None,
    ) -> np.ndarray:
        """Discrete cell states the sense path effectively sees: an element
        whose drifted resistance crossed the LRS/HRS midpoint
        ``sqrt(r_lrs * r_hrs)`` reads as the *other* state (retention
        failure).  At t_eff = 0 this is the identity."""
        hw = hw or _default_hw()
        r1, r2 = self.resistances(cells, t_since_write, reads_since_write, hw)
        mid = math.sqrt(hw.r_lrs * hw.r_hrs)
        return _PAIR_TO_CELL[(r1 < mid).astype(int), (r2 < mid).astype(int)]

    def cell_resistances(
        self, cells: np.ndarray, t_since_write, reads_since_write,
        hw=None,
    ) -> tuple[np.ndarray, np.ndarray]:
        """Per-cell effective resistance in the match and mismatch search
        states — the input to ``core.energy.sensing_margins``.

        On a match the searched branch runs through the cell's HRS-state
        element (the ON transistor in series with it, the other branch
        through the OFF transistor); on a mismatch through its LRS-state
        element.  CELL_X / CELL_MM cells use the stored element roles
        unchanged (both elements share a state, so the branch choice only
        picks which drift sample applies)."""
        hw = hw or _default_hw()
        cells = np.asarray(cells)
        r1, r2 = self.resistances(cells, t_since_write, reads_since_write, hw)
        r1_lrs = np.isin(cells, (CELL_1, CELL_MM))
        hrs_elem = np.where(r1_lrs, r2, r1)   # HRS-state element of the pair
        lrs_elem = np.where(r1_lrs, r1, r2)   # LRS-state element of the pair
        r_match = _par_np(hrs_elem + hw.r_on, lrs_elem + hw.r_off)
        r_mismatch = _par_np(lrs_elem + hw.r_on, hrs_elem + hw.r_off)
        return r_match, r_mismatch

    def flip_threshold(self, hw=None) -> float:
        """Walk factor at which an LRS element reads as HRS (and, scaled by
        1/hrs_drift_scale, vice versa): ``sqrt(r_hrs / r_lrs)``."""
        hw = hw or _default_hw()
        return math.sqrt(hw.r_hrs / hw.r_lrs)


def _par_np(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    return a * b / (a + b)


def _default_hw():
    from .energy import DEFAULT_HW

    return DEFAULT_HW


def sample_drift(
    shape: tuple[int, ...],
    spec: DriftSpec,
    rng: Optional[np.random.Generator] = None,
) -> DriftModel:
    """Sample one chip's persistent per-element drift exponents:
    ``ν_elem = |N(nu, nu_sigma)|`` per resistive element (rng required
    whenever nu_sigma > 0 — the fleet must not silently share one chip)."""
    if spec.nu_sigma > 0:
        rng = _require_rng(rng, "sample_drift")
        nu_r1 = np.abs(rng.normal(spec.nu, spec.nu_sigma, shape))
        nu_r2 = np.abs(rng.normal(spec.nu, spec.nu_sigma, shape))
    else:
        nu_r1 = np.full(shape, float(spec.nu))
        nu_r2 = np.full(shape, float(spec.nu))
    return DriftModel(spec=spec, nu_r1=nu_r1, nu_r2=nu_r2)


def noisy_inputs(
    X: np.ndarray,
    sigma_in: float,
    rng: Optional[np.random.Generator] = None,
) -> np.ndarray:
    """Add input-encoding noise to (normalized) features (paper: σ_in sweep).

    .. versionchanged:: 0.8
       ``rng`` is required whenever noise is actually drawn; the silent
       ``default_rng(0)`` fallback was removed.
    """
    if sigma_in <= 0:
        return np.asarray(X, dtype=np.float64)
    rng = _require_rng(rng, "noisy_inputs")
    X = np.asarray(X, dtype=np.float64)
    return X + rng.normal(0.0, sigma_in, size=X.shape)
