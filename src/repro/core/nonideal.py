"""Hardware non-idealities (paper §II.C.2, Table I, Fig 7/8).

Three mechanisms:
  * Stuck-At-Faults: each of the two resistive elements of a 2T2R cell
    independently sticks to HRS (SA0, prob p_sa0) or LRS (SA1, prob p_sa1).
    The resulting {R1, R2} pair maps back to a cell state, including the
    pathological {LRS, LRS} = always-mismatch (Table I).
  * SA manufacturing variability: handled inside ``simulate`` (σ_sa offsets on
    V_ref of individual sense amplifiers).
  * Input encoding noise: N(0, σ_in) added to normalized features before
    encoding.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .lut import CELL_0, CELL_1, CELL_MM, CELL_X

__all__ = ["NonIdealSpec", "IDEAL", "apply_saf", "noisy_inputs", "CELL_TO_PAIR"]


@dataclasses.dataclass(frozen=True)
class NonIdealSpec:
    """One object grouping the paper's three non-ideality mechanisms.

    Replaces the sprawling ``p_sa0/p_sa1/sa_sigma/sigma_in`` keyword lists on
    the inference entry points (``DT2CAM.infer`` keeps backward-compatible
    keyword shims for one release).

    p_sa0 / p_sa1: per-resistive-element stuck-at-HRS / stuck-at-LRS fault
        probabilities (Table I).
    sa_sigma: sense-amplifier V_ref manufacturing variability σ [V].
    sigma_in: input-encoding noise σ on normalized features.
    """

    p_sa0: float = 0.0
    p_sa1: float = 0.0
    sa_sigma: float = 0.0
    sigma_in: float = 0.0

    def __post_init__(self) -> None:
        for f in ("p_sa0", "p_sa1", "sa_sigma", "sigma_in"):
            if getattr(self, f) < 0:
                raise ValueError(f"{f} must be >= 0")
        if self.p_sa0 + self.p_sa1 > 1.0:
            raise ValueError("p_sa0 + p_sa1 must be <= 1")

    @property
    def is_ideal(self) -> bool:
        return (self.p_sa0 == 0 and self.p_sa1 == 0
                and self.sa_sigma == 0 and self.sigma_in == 0)

    @property
    def has_saf(self) -> bool:
        return self.p_sa0 > 0 or self.p_sa1 > 0


IDEAL = NonIdealSpec()

# cell state -> (R1 is LRS?, R2 is LRS?) — Table I encoding
CELL_TO_PAIR = {
    CELL_0: (False, True),   # {HRS, LRS}
    CELL_1: (True, False),   # {LRS, HRS}
    CELL_X: (False, False),  # {HRS, HRS}
    CELL_MM: (True, True),   # {LRS, LRS}
}
_PAIR_TO_CELL = np.zeros((2, 2), dtype=np.int8)
for _c, (_a, _b) in CELL_TO_PAIR.items():
    _PAIR_TO_CELL[int(_a), int(_b)] = _c


def apply_saf(
    cells: np.ndarray,
    p_sa0: float,
    p_sa1: float,
    rng: Optional[np.random.Generator] = None,
) -> np.ndarray:
    """Inject stuck-at faults into a cell-state array (any shape).

    Each resistive element independently becomes stuck-at-HRS with prob p_sa0
    and stuck-at-LRS with prob p_sa1 (mutually exclusive draws; if both fire
    the draw is resolved 50/50, matching independent physical defects)."""
    rng = rng or np.random.default_rng(0)
    cells = np.asarray(cells)
    r1_lrs = np.isin(cells, (CELL_1, CELL_MM))
    r2_lrs = np.isin(cells, (CELL_0, CELL_MM))

    def stick(is_lrs: np.ndarray) -> np.ndarray:
        u = rng.random(cells.shape)
        stuck0 = u < p_sa0
        stuck1 = (u >= p_sa0) & (u < p_sa0 + p_sa1)
        # tie-break region when p_sa0 + p_sa1 > 1 is impossible for paper's
        # ranges (max 5% + 5%); assert to be safe.
        out = is_lrs.copy()
        out[stuck0] = False  # stuck at HRS
        out[stuck1] = True   # stuck at LRS
        return out

    if p_sa0 + p_sa1 > 1.0:
        raise ValueError("p_sa0 + p_sa1 must be <= 1")
    new_r1 = stick(r1_lrs)
    new_r2 = stick(r2_lrs)
    return _PAIR_TO_CELL[new_r1.astype(int), new_r2.astype(int)]


def noisy_inputs(
    X: np.ndarray,
    sigma_in: float,
    rng: Optional[np.random.Generator] = None,
) -> np.ndarray:
    """Add input-encoding noise to (normalized) features (paper: σ_in sweep)."""
    if sigma_in <= 0:
        return np.asarray(X, dtype=np.float64)
    rng = rng or np.random.default_rng(0)
    X = np.asarray(X, dtype=np.float64)
    return X + rng.normal(0.0, sigma_in, size=X.shape)
