"""ReCAM functional synthesizer — simulation step (paper §II.C.2).

Evaluates a synthesized TCAM layout functionally (match/mismatch per row per
column division, selective-precharge active-row propagation) and converts the
activity trace into energy / latency / throughput / accuracy numbers via the
analog model in ``energy.py``.

This module is the *numpy oracle*; the JAX / Pallas fast paths in
``repro.kernels`` are validated against it bit-exactly (ideal hardware) and
statistically (non-ideal hardware).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterator, Optional

import numpy as np

from .energy import DEFAULT_HW, HardwareParams, f_max, t_cwd, t_opt
from .lut import bitplanes
from .synth import TCAMLayout

__all__ = ["SimResult", "mismatch_counts", "simulate", "sense_voltage"]


@dataclasses.dataclass
class SimResult:
    predictions: np.ndarray          # (batch,) int32 — argmax surviving row class
    survivors: np.ndarray            # (batch,) int32 — surviving row index (-1 none)
    n_survivors: np.ndarray          # (batch,) int32
    active_evals: np.ndarray         # (batch,) int64 — Σ active row-divisions (N_a)
    energy_per_dec: np.ndarray       # (batch,) J
    latency_s: float                 # sequential T_total per input
    throughput_seq: float            # dec/s, sequential column divisions
    throughput_pipe: float           # dec/s, pipelined column divisions
    s: int
    n_cwd: int
    n_rwd: int

    @property
    def mean_energy(self) -> float:
        return float(self.energy_per_dec.mean())

    @property
    def edp(self) -> float:
        """Energy-delay product per decision (J·s), sequential operation."""
        return self.mean_energy * self.latency_s

    def accuracy(self, labels: np.ndarray) -> float:
        return float((self.predictions == np.asarray(labels)).mean())

    # ``kernels.tcam_infer`` once returned a bare 5-tuple and SimResult kept
    # a one-release tuple-unpacking shim; the shim has expired.  Keeping the
    # method (raising) turns old unpacking call sites into an actionable
    # error instead of a generic "cannot unpack non-iterable" TypeError.
    def __iter__(self) -> "Iterator[np.ndarray]":
        raise TypeError(
            "tuple-unpacking a SimResult was removed; use the named fields "
            "(.predictions, .survivors, .n_survivors, .active_evals, "
            ".energy_per_dec) instead"
        )


def sense_voltage(
    k_mismatch: np.ndarray,
    n_eff: np.ndarray,
    s: int,
    hw: HardwareParams = DEFAULT_HW,
) -> np.ndarray:
    """Match-line voltage at the design sensing time T_opt(S) for rows with
    ``k_mismatch`` mismatching cells out of ``n_eff`` unmasked cells."""
    k = np.asarray(k_mismatch, dtype=np.float64)
    n = np.asarray(n_eff, dtype=np.float64)
    g_match = np.maximum(n - k, 0.0) / hw.r_cell_match
    g_mm = k / hw.r_cell_mismatch
    r_row = 1.0 / np.maximum(g_match + g_mm, 1e-12)
    return hw.v_dd * np.exp(-t_opt(s, hw) / (r_row * hw.c_in))


def mismatch_counts(cells: np.ndarray, xbits: np.ndarray) -> np.ndarray:
    """(batch, rows) mismatch counts — the MXU formulation (DESIGN.md §2):
    mism = X·is0ᵀ + (1-X)·is1ᵀ  (CELL_MM sets both planes -> always +1).

    float32 BLAS matmul: exact because counts <= width < 2^24.
    """
    is0, is1 = bitplanes(cells)
    x = xbits.astype(np.float32)
    out = x @ is0.T.astype(np.float32) + (1.0 - x) @ is1.T.astype(np.float32)
    return np.rint(out).astype(np.int64)


def _division_mismatches(
    layout: TCAMLayout, xpad: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """Per column division d: (batch, rows, n_cwd) mismatch counts and
    (n_cwd,) effective (unmasked) cell count per row.

    Masked cells: padding columns beyond the decoder+LUT width in the *last*
    column division are masked (OFF-OFF) and contribute neither mismatches nor
    match-line conductance (paper §II.C.1 'Input Processing')."""
    s, n_cwd = layout.s, layout.n_cwd
    b = xpad.shape[0]
    rows = layout.cells.shape[0]
    counts = np.zeros((b, rows, n_cwd), dtype=np.int64)
    used = 1 + layout.width  # decoder column + encoded LUT bits
    n_eff = np.zeros(n_cwd, dtype=np.int64)
    for d in range(n_cwd):
        lo, hi = d * s, (d + 1) * s
        real = max(0, min(hi, used) - lo)  # unmasked columns in this division
        n_eff[d] = real
        if real == 0:
            continue
        counts[:, :, d] = mismatch_counts(
            layout.cells[:, lo : lo + real], xpad[:, lo : lo + real]
        )
    return counts, n_eff


def simulate(
    layout: TCAMLayout,
    xbits: np.ndarray,
    *,
    hw: HardwareParams = DEFAULT_HW,
    selective_precharge: bool = True,
    sa_sigma: float = 0.0,
    rng: Optional[np.random.Generator] = None,
) -> SimResult:
    """Functionally evaluate encoded inputs against the tiled layout.

    sa_sigma > 0 enables the sense-amplifier manufacturing-variability model:
    each physical SA (one per row per column division) gets a fixed offset
    ~N(0, sa_sigma) on its reference voltage; a row's sensed match/mismatch is
    decided by comparing the analog match-line voltage (from the *exact*
    mismatch count) against V_ref + offset (paper §II.C.2).
    """
    xpad = layout.pad_inputs(np.asarray(xbits, dtype=np.uint8))
    counts, n_eff = _division_mismatches(layout, xpad)
    b, rows, n_cwd = counts.shape
    s = layout.s

    if sa_sigma > 0.0:
        rng = rng or np.random.default_rng(0)
        offsets = rng.normal(0.0, sa_sigma, size=(rows, n_cwd))
        v_ml = sense_voltage(counts, n_eff[None, None, :], s, hw)
        # V_ref per division: midpoint of (V_fm, V_1mm) for that division's
        # effective row size; the last division uses V_ref2 (masked cells).
        v_fm = sense_voltage(np.zeros(n_cwd), n_eff, s, hw)
        v_1mm = sense_voltage(np.ones(n_cwd), n_eff, s, hw)
        v_ref = 0.5 * (v_fm + v_1mm)
        match = v_ml > (v_ref[None, None, :] + offsets[None, :, :])
    else:
        match = counts == 0

    # Selective precharge: active[d] = matched all previous divisions.
    # active_in[:, :, d] == row evaluated (precharged + sensed) in division d.
    prior = np.cumprod(
        np.concatenate([np.ones((b, rows, 1), bool), match[:, :, :-1]], axis=2),
        axis=2,
    ).astype(bool)
    survive = prior[:, :, -1] & match[:, :, -1]

    if selective_precharge:
        active_evals = prior.sum(axis=(1, 2)).astype(np.int64)
    else:
        active_evals = np.full(b, rows * n_cwd, dtype=np.int64)

    n_survivors = survive.sum(axis=1).astype(np.int32)
    first = np.argmax(survive, axis=1).astype(np.int32)
    survivors = np.where(n_survivors > 0, first, -1).astype(np.int32)
    predictions = np.where(
        n_survivors > 0, layout.classes[np.maximum(survivors, 0)], 0
    ).astype(np.int32)

    energy = active_evals.astype(np.float64) * hw.e_row + hw.e_mem
    fm = f_max(s, hw)
    latency = n_cwd * t_cwd(s, hw) + hw.t_mem
    return SimResult(
        predictions=predictions,
        survivors=survivors,
        n_survivors=n_survivors,
        active_evals=active_evals,
        energy_per_dec=energy,
        latency_s=latency,
        throughput_seq=fm / n_cwd,
        throughput_pipe=fm / hw.pipeline_ii_cycles,
        s=s,
        n_cwd=n_cwd,
        n_rwd=layout.n_rwd,
    )
