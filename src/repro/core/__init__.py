"""DT2CAM core: the paper's contribution as a composable library.

Layers (bottom-up): cart (DT training) -> reduce (tree parsing + column
reduction) -> encode (ternary adaptive encoding) -> lut (bitplane LUT) ->
synth (S×S tiling, decoder column) -> simulate (functional sim + selective
precharge) -> energy (analog ReCAM model) -> nonideal (SAF / SA-var / noise).
``compiler.DT2CAM`` is the one-call front door.
"""
from .cart import DecisionTree, predict, train_tree, tree_leaf_ids, tree_paths
from .compiler import (
    DT2CAM,
    CompiledDT,
    FeatureMismatch,
    check_feature_count,
    compile_tree,
)
from .encode import encode_inputs, encode_table, span_code, unary_code
from .energy import (
    DEFAULT_HW,
    HardwareParams,
    SenseMargins,
    bank_figures,
    choose_tile_size,
    dynamic_range,
    f_max,
    forest_figures,
    max_cells_per_row,
    mismatch_probability,
    reprogram_figures,
    sensing_margins,
    t_cwd,
    t_opt,
    write_energy,
)
from .lut import CELL_0, CELL_1, CELL_MM, CELL_X, TernaryLUT, bitplanes
from .nonideal import (
    IDEAL,
    DriftModel,
    DriftSpec,
    NonIdealSpec,
    SAFMask,
    apply_saf,
    apply_saf_mask,
    noisy_inputs,
    sample_drift,
    sample_saf,
)
from .reduce import CMP_BETWEEN, CMP_GT, CMP_LE, CMP_NONE, RuleTable, reduce_tree
from .simulate import SimResult, mismatch_counts, simulate
from .synth import TCAMLayout, synthesize

__all__ = [
    "DecisionTree", "predict", "train_tree", "tree_paths", "tree_leaf_ids",
    "DT2CAM", "CompiledDT", "compile_tree",
    "FeatureMismatch", "check_feature_count",
    "encode_inputs", "encode_table", "span_code", "unary_code",
    "DEFAULT_HW", "HardwareParams", "choose_tile_size", "dynamic_range",
    "f_max", "max_cells_per_row", "t_cwd", "t_opt",
    "bank_figures", "forest_figures", "write_energy", "reprogram_figures",
    "SenseMargins", "sensing_margins", "mismatch_probability",
    "CELL_0", "CELL_1", "CELL_MM", "CELL_X", "TernaryLUT", "bitplanes",
    "IDEAL", "NonIdealSpec", "SAFMask", "apply_saf", "apply_saf_mask",
    "noisy_inputs", "sample_saf",
    "DriftSpec", "DriftModel", "sample_drift",
    "CMP_BETWEEN", "CMP_GT", "CMP_LE", "CMP_NONE", "RuleTable", "reduce_tree",
    "SimResult", "mismatch_counts", "simulate",
    "TCAMLayout", "synthesize",
]
