"""ReCAM functional synthesizer — mapping step (paper §II.C.1, Fig 3).

Splits the encoded LUT into S×S TCAM tiles:
  N_rwd = ⌈rows / S⌉ row-wise tiles (operate in parallel),
  N_cwd = ⌈(width + 1) / S⌉ column-wise tiles (operate sequentially; the +1 is
  the reserved decoder column at bit 0 of the first column division).

Padding cells are don't-cares; rogue rows (padding rows beyond the LUT) carry
a decoder-column '1' so the input's padded leading '0' forcibly mismatches
them; their class cells are populated with random valid classes (paper text).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from .lut import CELL_1, CELL_X, TernaryLUT

__all__ = ["TCAMLayout", "synthesize"]


@dataclasses.dataclass
class TCAMLayout:
    """Tiled TCAM arrays + class memory.

    cells:    (N_rwd·S, N_cwd·S) int8 cell states, decoder column at [:, 0].
    classes:  (N_rwd·S,) int32 (rogue rows hold random valid classes).
    class_bits: (N_rwd·S, ceil(log2 C)) uint8 — 1T1R class storage.
    s:        tile edge S.  n_rwd, n_cwd: tile grid.  n_rows/width: LUT dims.
    """

    cells: np.ndarray
    classes: np.ndarray
    class_bits: np.ndarray
    s: int
    n_rwd: int
    n_cwd: int
    n_rows: int
    width: int
    n_classes: int

    @property
    def n_tiles(self) -> int:
        return self.n_rwd * self.n_cwd

    @property
    def n_spares(self) -> int:
        """Physical rows beyond the LUT (rogue rows) — the spare-row pool
        available to ``repro.reliability.repair``."""
        return int(self.cells.shape[0]) - self.n_rows

    @property
    def spare_row_indices(self) -> np.ndarray:
        return np.arange(self.n_rows, self.cells.shape[0])

    @property
    def n_cells(self) -> int:
        """Total TCAM cells across tiles (area / energy accounting)."""
        return self.n_tiles * self.s * self.s

    def pad_inputs(self, xbits: np.ndarray) -> np.ndarray:
        """(batch, width) encoded inputs -> (batch, N_cwd·S) search words:
        a leading '0' decoder bit, then the code, then zero padding (the
        padded LUT cells are don't-care/masked so the pad value is moot)."""
        b = xbits.shape[0]
        out = np.zeros((b, self.n_cwd * self.s), dtype=np.uint8)
        out[:, 1 : 1 + self.width] = xbits
        return out

    def area_m2(self, hw=None) -> float:
        """Eqn 11 with the calibrated 16nm cells."""
        from .energy import DEFAULT_HW

        hw = hw or DEFAULT_HW
        s = self.s
        tcam = self.n_tiles * (
            s * s * hw.a_2t2r + s * (hw.a_sa + hw.a_dff + hw.a_sp)
        )
        cbits = max(1, math.ceil(math.log2(max(self.n_classes, 2))))
        cls = s * cbits * (hw.a_1t1r + hw.a_sa2)
        return tcam + cls


def synthesize(
    lut: TernaryLUT, s: int, *, seed: int = 0, spare_rows: int = 0
) -> TCAMLayout:
    """Map the encoded LUT into S×S tiles with decoder column + rogue rows.

    ``spare_rows`` guarantees at least that many rogue rows beyond the LUT
    (adding row-wise tiles as needed) so the reliability layer has a spare
    pool to remap defective rows onto; the natural tile padding already
    provides ``n_rwd·s - rows`` spares for free.
    """
    if spare_rows < 0:
        raise ValueError("spare_rows must be >= 0")
    rows, width = lut.n_rows, lut.width
    n_rwd = max(1, math.ceil((rows + spare_rows) / s))
    n_cwd = max(1, math.ceil((width + 1) / s))
    total_rows, total_cols = n_rwd * s, n_cwd * s

    cells = np.full((total_rows, total_cols), CELL_X, dtype=np.int8)
    cells[:rows, 1 : 1 + width] = lut.cells
    # decoder column: LUT rows store '0' (matches the padded input '0');
    # rogue rows store '1' -> forced mismatch.
    cells[:rows, 0] = 0
    cells[rows:, 0] = CELL_1

    rng = np.random.default_rng(seed)
    classes = np.empty(total_rows, dtype=np.int32)
    classes[:rows] = lut.classes
    classes[rows:] = rng.integers(0, lut.n_classes, size=total_rows - rows)

    cbits = max(1, math.ceil(math.log2(max(lut.n_classes, 2))))
    shifts = np.arange(cbits - 1, -1, -1)
    class_bits = ((classes[:, None] >> shifts) & 1).astype(np.uint8)

    return TCAMLayout(
        cells=cells,
        classes=classes,
        class_bits=class_bits,
        s=s,
        n_rwd=n_rwd,
        n_cwd=n_cwd,
        n_rows=rows,
        width=width,
        n_classes=lut.n_classes,
    )
