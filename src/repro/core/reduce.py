"""Column reduction (paper §II.A.3).

Collapses the per-path condition lists produced by tree parsing into a single
rule per (row, feature): ``(comparator, Th1, Th2)`` with comparator semantics

  '0'  -> f <= Th1                 (Th2 = NaN)
  '1'  -> f >  Th1                 (Th2 = NaN)
  '2'  -> Th1 < f <= Th2
  NaN  -> no rule on this feature in this row

By CART construction the conditions on one feature along one path always
describe a contiguous interval, so the reduction is exact.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .cart import DecisionTree, tree_paths

__all__ = ["RuleTable", "CMP_LE", "CMP_GT", "CMP_BETWEEN", "CMP_NONE", "reduce_tree"]

CMP_LE = 0       # f <= Th1
CMP_GT = 1       # f > Th1
CMP_BETWEEN = 2  # Th1 < f <= Th2
CMP_NONE = 3     # no rule ('NaN' in the paper)


@dataclasses.dataclass
class RuleTable:
    """Reduced rule table: one row per DT path.

    comparator: (rows, features) int8 in {CMP_LE, CMP_GT, CMP_BETWEEN, CMP_NONE}
    th1, th2:   (rows, features) float64 (NaN where unused)
    classes:    (rows,) int32 leaf class per path
    """

    comparator: np.ndarray
    th1: np.ndarray
    th2: np.ndarray
    classes: np.ndarray
    n_classes: int

    @property
    def n_rows(self) -> int:
        return int(self.comparator.shape[0])

    @property
    def n_features(self) -> int:
        return int(self.comparator.shape[1])

    def row_matches(self, X: np.ndarray) -> np.ndarray:
        """(batch, rows) bool — functional reference: does input match path?"""
        X = np.asarray(X, dtype=np.float64)
        b = X.shape[0]
        m = np.ones((b, self.n_rows), dtype=bool)
        for j in range(self.n_features):
            cmp_ = self.comparator[:, j][None, :]       # (1, rows)
            t1 = self.th1[:, j][None, :]
            t2 = self.th2[:, j][None, :]
            v = X[:, j][:, None]                        # (batch, 1)
            ok = np.where(
                cmp_ == CMP_LE, v <= t1,
                np.where(
                    cmp_ == CMP_GT, v > t1,
                    np.where(cmp_ == CMP_BETWEEN, (v > t1) & (v <= t2), True),
                ),
            )
            m &= ok
        return m


def reduce_tree(tree: DecisionTree) -> RuleTable:
    """Parse the tree into paths and reduce conditions per feature (§II.A.2-3)."""
    paths = tree_paths(tree)
    rows = len(paths)
    f = tree.n_features
    comparator = np.full((rows, f), CMP_NONE, dtype=np.int8)
    th1 = np.full((rows, f), np.nan)
    th2 = np.full((rows, f), np.nan)
    classes = np.zeros(rows, dtype=np.int32)
    for r, (conds, cls) in enumerate(paths):
        classes[r] = cls
        lo = np.full(f, -np.inf)  # strict lower bound: f > lo
        hi = np.full(f, np.inf)   # inclusive upper bound: f <= hi
        for feat, op, thr in conds:
            if op == "<=":
                hi[feat] = min(hi[feat], thr)
            else:
                lo[feat] = max(lo[feat], thr)
        for j in range(f):
            has_lo = np.isfinite(lo[j])
            has_hi = np.isfinite(hi[j])
            if has_lo and has_hi:
                comparator[r, j] = CMP_BETWEEN
                th1[r, j], th2[r, j] = lo[j], hi[j]
            elif has_hi:
                comparator[r, j] = CMP_LE
                th1[r, j] = hi[j]
            elif has_lo:
                comparator[r, j] = CMP_GT
                th1[r, j] = lo[j]
    return RuleTable(comparator, th1, th2, classes, tree.n_classes)
