"""Scrub-and-refresh scheduling for drifting ReCAM arrays.

A *scrub* reads a region's intended content and rewrites it in place; the
rewrite resets every element's drift clock (conductance walks restart from
the freshly-programmed state).  The scheduler's job is deciding *when* to
refresh *which* rows, trading refresh energy + endurance pulses against the
accuracy loss of serving from out-of-margin cells:

* ``periodic`` policy — refresh any row older than ``period_s`` (DRAM-style
  blanket refresh; simple, ignores the actual margins).
* ``margin`` policy — refresh rows whose worst-case sensing margin (from
  ``core.energy.sensing_margins`` over the drifted resistances) fell below
  ``margin_v`` (condition-based; touches only the rows that need it).

Refreshes are lowered through the lifecycle write machinery: ``plan_refresh``
emits a ``WritePlan`` (kind ``"refresh"``) whose SET/RESET pulse maps feed
``core.energy.reprogram_figures`` (energy/time) and
``lifecycle.WearTracker.record`` (endurance) exactly like a redeploy — a
scrubbing deployment sees its refresh overhead in the same ledgers as its
model updates.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional

import numpy as np

from ..core.energy import DEFAULT_HW, HardwareParams, sensing_margins
from ..core.lut import CELL_0, CELL_1
from ..core.nonideal import DriftModel
from ..lifecycle.delta import WritePlan, cell_planes

__all__ = ["ScrubPolicy", "ScrubReport", "ScrubScheduler", "layout_margins",
           "plan_refresh"]


@dataclasses.dataclass(frozen=True)
class ScrubPolicy:
    """When is a row due for refresh?

    kind='margin': when its sensing margin drops to <= ``margin_v`` volts.
    kind='periodic': when its age since last write reaches ``period_s``.
    ``max_rows`` bounds one scrub pass (worst rows first); None = unbounded.
    """

    kind: str = "margin"
    margin_v: float = 0.15
    period_s: float = 3600.0
    max_rows: Optional[int] = None

    def __post_init__(self) -> None:
        if self.kind not in ("margin", "periodic"):
            raise ValueError(
                f"unknown scrub policy kind {self.kind!r} "
                "(expected 'margin' or 'periodic')"
            )
        if self.period_s <= 0:
            raise ValueError(f"period_s must be > 0, got {self.period_s}")
        if self.max_rows is not None and self.max_rows < 1:
            raise ValueError(f"max_rows must be >= 1, got {self.max_rows}")


def plan_refresh(
    cells: np.ndarray,
    rows: Iterable[int],
    *,
    used: Optional[int] = None,
) -> WritePlan:
    """Refresh plan: one reinforcing pulse per resistive element of every
    cell in ``rows`` over the first ``used`` columns (SET for an LRS element,
    RESET for an HRS element — re-asserting the programmed state).

    The plan's ``old == new`` (a refresh changes no cell *state*), so
    ``apply()`` is the identity; what it carries is the pulse maps — the
    energy/time/endurance cost of the pass.
    """
    cells = np.asarray(cells)
    n_rows, n_cols = cells.shape
    used = n_cols if used is None else min(used, n_cols)
    rows = np.unique(np.asarray(list(rows), dtype=np.int64))
    if rows.size and (rows.min() < 0 or rows.max() >= n_rows):
        raise ValueError("refresh row index out of range")

    r1_lrs, r2_lrs = cell_planes(cells)
    sel = np.zeros((n_rows, n_cols), dtype=bool)
    sel[rows, :used] = True
    set_map = (sel & r1_lrs).astype(np.int16) + (sel & r2_lrs).astype(np.int16)
    reset_map = (sel & ~r1_lrs).astype(np.int16) \
        + (sel & ~r2_lrs).astype(np.int16)
    rr, cc = np.nonzero(sel)
    return WritePlan(
        kind="refresh",
        shape=(n_rows, n_cols),
        rows=rr.astype(np.int64),
        cols=cc.astype(np.int64),
        old=cells[rr, cc],
        new=cells[rr, cc],
        set_map=set_map,
        reset_map=reset_map,
        n_cells_written=int(sel.sum()),
        class_set=0,
        class_reset=0,
        class_rows=np.zeros(0, np.int64),
    )


def layout_margins(
    layout,
    drift: DriftModel,
    t_since_write,
    reads_since_write,
    hw: HardwareParams = DEFAULT_HW,
):
    """Per-row ``SenseMargins`` of a layout under drift.

    ``layout`` is duck-typed (needs ``cells``, ``s``, ``width``);
    ``t_since_write`` / ``reads_since_write`` are per-row or scalar, usually
    straight from a ``ScrubScheduler``.  Only determinate (CELL_0/CELL_1)
    cells can mismatch; CELL_X don't-cares contribute match-branch
    conductance only, mirroring the functional simulator.
    """
    cells = np.asarray(layout.cells)
    r_match, r_mismatch = drift.cell_resistances(
        cells, t_since_write, reads_since_write, hw
    )
    return sensing_margins(
        r_match, r_mismatch,
        s=int(layout.s), used=1 + int(layout.width), hw=hw,
        determinate=np.isin(cells, (CELL_0, CELL_1)),
    )


@dataclasses.dataclass(frozen=True)
class ScrubReport:
    """Outcome of one scrub pass."""

    t: float                      # virtual time of the pass
    policy: str                   # policy kind that selected the rows
    rows_due: int                 # rows the policy wanted refreshed
    rows_refreshed: np.ndarray    # (k,) rows actually refreshed
    rows_skipped: np.ndarray      # (m,) due rows excluded (blocked/capped)
    figures: dict                 # reprogram_figures of the refresh plan
    margin_min_v: Optional[float]  # worst pre-scrub margin (margin policy)

    @property
    def n_refreshed(self) -> int:
        return int(self.rows_refreshed.shape[0])

    def summary(self) -> dict:
        return {
            "t": self.t,
            "policy": self.policy,
            "rows_due": self.rows_due,
            "rows_refreshed": self.n_refreshed,
            "rows_skipped": int(self.rows_skipped.shape[0]),
            "pulses": self.figures["pulses"],
            "energy_j": self.figures["energy_j"],
            "time_s": self.figures["time_s"],
            "margin_min_v": self.margin_min_v,
        }


class ScrubScheduler:
    """Per-row stress bookkeeping + refresh scheduling on a virtual clock.

    Tracks, for one physical array of ``n_rows`` rows: the virtual time each
    row was last (re)written and the searches it has served since — the
    ``(time_since_write, reads_since_write)`` pair ``DriftModel`` evolves
    resistances over.  ``advance``/``note_reads`` are driven by the serving
    loop; ``note_write`` by any programming pass (redeploy, repair, refresh).

    Composition: pass ``wear=`` a ``lifecycle.WearTracker`` and every refresh
    plan executed through ``scrub()`` debits the shared endurance ledger;
    pass ``blocked=`` (e.g. ``RepairReport.blocked_rows``) to ``due``/
    ``scrub`` so decoder-disabled rows are never refreshed — they carry no
    live content and the pulses would be wasted endurance.
    """

    def __init__(
        self,
        n_rows: int,
        *,
        policy: ScrubPolicy = ScrubPolicy(),
        wear=None,
        hw: HardwareParams = DEFAULT_HW,
    ) -> None:
        if n_rows < 1:
            raise ValueError(f"n_rows must be >= 1, got {n_rows}")
        self.policy = policy
        self.wear = wear
        self.hw = hw
        self.now = 0.0
        self.t_written = np.zeros(n_rows, dtype=np.float64)
        self.reads = np.zeros(n_rows, dtype=np.int64)
        self.scrubs = 0
        self.rows_refreshed_total = 0
        self.refresh_energy_j = 0.0
        self.refresh_pulses = 0

    @property
    def n_rows(self) -> int:
        return int(self.t_written.shape[0])

    # -- stress clock ------------------------------------------------------
    def advance(self, dt: float) -> float:
        """Advance the virtual clock by dt seconds; returns the new now."""
        if dt < 0:
            raise ValueError(f"dt must be >= 0, got {dt}")
        self.now += float(dt)
        return self.now

    def note_reads(self, n: int = 1,
                   rows: Optional[Iterable[int]] = None) -> None:
        """Record n searches against all rows (every search precharges and
        senses every live row in the first column division) or against a
        subset."""
        if rows is None:
            self.reads += int(n)
        else:
            self.reads[np.asarray(list(rows), dtype=np.int64)] += int(n)

    def note_write(self, rows: Optional[Iterable[int]] = None) -> None:
        """A programming pass rewrote these rows (None = whole array): their
        drift clocks restart."""
        if rows is None:
            self.t_written[:] = self.now
            self.reads[:] = 0
        else:
            idx = np.asarray(list(rows), dtype=np.int64)
            self.t_written[idx] = self.now
            self.reads[idx] = 0

    def ages(self) -> np.ndarray:
        """(rows,) seconds since each row's last write."""
        return self.now - self.t_written

    # -- scheduling --------------------------------------------------------
    def _hit(self, margins: Optional[np.ndarray]) -> np.ndarray:
        """All rows the policy flags, worst-first, before blocked/cap."""
        if self.policy.kind == "margin":
            if margins is None:
                raise ValueError("margin policy needs per-row margins")
            margins = np.asarray(margins, dtype=np.float64)
            if margins.shape != (self.n_rows,):
                raise ValueError(
                    f"margins shape {margins.shape} != ({self.n_rows},)"
                )
            hit = margins <= self.policy.margin_v
            order = np.argsort(margins, kind="stable")  # worst margin first
        else:
            age = self.ages()
            hit = age >= self.policy.period_s
            order = np.argsort(-age, kind="stable")     # oldest first
        return order[hit[order]].astype(np.int64)

    def due(
        self,
        margins: Optional[np.ndarray] = None,
        *,
        blocked: Iterable[int] = (),
    ) -> np.ndarray:
        """Rows due for refresh under the policy, worst-first, minus
        ``blocked``, capped at ``policy.max_rows``.

        The margin policy needs ``margins`` — the per-row overall margin
        (``SenseMargins.margin`` / ``layout_margins(...)``, computed by the
        caller who owns the ``DriftModel``).
        """
        due = self._hit(margins)
        blocked = np.asarray(list(blocked), dtype=np.int64)
        if blocked.size:
            due = due[~np.isin(due, blocked)]
        if self.policy.max_rows is not None:
            due = due[: self.policy.max_rows]
        return due

    def scrub(
        self,
        cells: np.ndarray,
        margins: Optional[np.ndarray] = None,
        *,
        used: Optional[int] = None,
        blocked: Iterable[int] = (),
        force_rows: Optional[Iterable[int]] = None,
    ) -> tuple[WritePlan, ScrubReport]:
        """One scrub pass: select due rows (or ``force_rows``), emit the
        refresh plan, debit the wear ledger, restart the rows' drift clocks.

        Returns (plan, report); the *caller* owns rewriting the physical
        array contents from the intent (in simulation: re-deriving the
        served grid from the intent at zero drift).
        """
        blocked = np.asarray(list(blocked), dtype=np.int64)
        if force_rows is not None:
            want = np.unique(np.asarray(list(force_rows), dtype=np.int64))
        else:
            want = self._hit(margins)
        due = want[~np.isin(want, blocked)] if blocked.size else want
        if force_rows is None and self.policy.max_rows is not None:
            due = due[: self.policy.max_rows]
        plan = plan_refresh(cells, due, used=used)
        figs = plan.figures(self.hw)
        if due.size:
            if self.wear is not None:
                self.wear.record(plan)
            self.note_write(due)
        self.scrubs += 1
        self.rows_refreshed_total += int(due.size)
        self.refresh_energy_j += figs["energy_j"]
        self.refresh_pulses += figs["pulses"]
        report = ScrubReport(
            t=self.now,
            policy="forced" if force_rows is not None else self.policy.kind,
            rows_due=int(want.size),
            rows_refreshed=due,
            rows_skipped=np.setdiff1d(want, due),
            figures=figs,
            margin_min_v=(float(np.min(margins))
                          if margins is not None and np.size(margins)
                          else None),
        )
        return plan, report

    def snapshot(self) -> dict:
        ages = self.ages()
        return {
            "now_s": self.now,
            "rows": self.n_rows,
            "max_age_s": float(ages.max()) if ages.size else 0.0,
            "max_reads": int(self.reads.max()) if self.reads.size else 0,
            "scrub_passes": self.scrubs,
            "rows_refreshed_total": self.rows_refreshed_total,
            "refresh_energy_j": self.refresh_energy_j,
            "refresh_pulses": self.refresh_pulses,
            "policy": dataclasses.asdict(self.policy),
        }
