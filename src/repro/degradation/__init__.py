"""Temporal degradation management: drift-aware scrubbing & refresh.

Static non-idealities (stuck-at faults, SA variability, input noise) are
modelled in ``core.nonideal`` and detected/repaired by ``repro.reliability``.
This package owns the *temporal* axis: memristive conductance drifts and
retention decays between writes (Pedretti et al. 2021 call this out as a
first-order threat to in-memory tree inference), so a long-running deployment
must track per-row stress, watch sensing margins shrink, and refresh rows
before they functionally misread.

Building blocks:

* ``ScrubScheduler`` — per-row write timestamps + read counts on a virtual
  clock; ``due()`` picks the rows to refresh under a ``ScrubPolicy``
  (margin-threshold or periodic).
* ``plan_refresh`` — lowers a refresh to the lifecycle ``WritePlan``
  machinery (one reinforcing pulse per resistive element), so refresh
  energy/time surface through ``core.energy.reprogram_figures`` and the
  pulses debit the same ``WearTracker`` endurance ledger as redeploys.
* ``layout_margins`` — glue from a layout + ``DriftModel`` + per-row stress
  to ``core.energy.sensing_margins``.

``serve.TCAMServer`` wires these into a background maintenance pass; see
``benchmarks/degradation_bench.py`` for the accuracy-guardrail campaign.
"""
from .scheduler import (
    ScrubPolicy,
    ScrubReport,
    ScrubScheduler,
    layout_margins,
    plan_refresh,
)

__all__ = [
    "ScrubPolicy",
    "ScrubReport",
    "ScrubScheduler",
    "layout_margins",
    "plan_refresh",
]
