"""Spare-row repair: remap defective TCAM rows onto the spare-row pool.

RETENTION-style resource lever: the synthesized array already carries rogue
rows beyond the LUT (``synthesize(..., spare_rows=...)`` guarantees a
minimum), and stuck-at faults are *persistent element* properties — so
repair is a remapping problem:

  1. take the BIST defect map, order defective LUT rows by priority
     (``row_utilization`` supplies traffic-weighted priority — heavy rules
     first — when data is available);
  2. *write-verify* each candidate (row, spare) pair: simulate the row-write
     through the spare's own stuck elements (``apply_saf_mask``) and grade
     the written row's behavior signature against the intended one — clean
     (identical), permissive-only (strictly fewer literals; accepted when
     ``allow_permissive``, the default: a slightly-too-permissive copy beats
     a dead rule), or damaged.  Assign rows to spares by maximum bipartite
     matching (Kuhn's augmenting paths, heavy rows first, clean edges
     preferred) — greedy first-fit strands later rows when compatible spares
     are scarce.  Rows left unmatched fall back to the least-damaged spare,
     taken only when it misbehaves on strictly fewer literal positions than
     the defective original;
  3. disable the defective original (write '1' into its decoder cell so it
     mismatches every query); if the decoder cell itself is stuck
     permissive, fall back to a *poison write* — program any healthy body
     cell to {LRS, LRS} (CELL_MM), which mismatches unconditionally;
  4. copy the row's class into the spare's class memory (classes +
     class_bits re-derived; priority is preserved because disabled originals
     drop out of the first-surviving-row argmax).

Spares are consumed left-to-right; when the pool runs dry the remaining
defective rows are reported in ``RepairReport.unrepaired`` — graceful
degradation, not an exception.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ..core.lut import CELL_1, CELL_MM, CELL_X
from ..core.nonideal import SAFMask, apply_saf_mask
from ..core.synth import TCAMLayout
from .bist import row_match, row_signatures

__all__ = ["RepairReport", "repair_layout", "row_utilization"]


@dataclasses.dataclass
class RepairReport:
    """Outcome of one repair pass (graceful-degradation accounting)."""

    assignments: dict[int, int]       # defective LUT row -> spare row
    permissive: list[int]             # spares accepted permissive-only
    best_effort: list[int]            # spares taken damaged-but-better
    disabled: list[int]               # originals successfully disabled
    ghosts: list[int]                 # rows that could not be silenced
    unrepaired: list[int]             # defective rows with no usable spare
    spares_used: int
    spares_left: int

    @property
    def degraded(self) -> bool:
        """True when the chip still misbehaves after repair (spares
        exhausted or un-silenceable ghost rows)."""
        return bool(self.unrepaired or self.ghosts)

    @property
    def rows_repaired(self) -> int:
        return len(self.assignments)

    @property
    def blocked_rows(self) -> np.ndarray:
        """Physical rows that must not receive live content in a later
        reprogramming pass: defective originals remapped onto spares, rows
        left unrepaired, and un-silenceable ghosts.  This is the composition
        point with the lifecycle wear-leveling remapper
        (``repro.lifecycle.wear_level_rows(..., forbidden=report.blocked_rows)``).
        """
        return np.unique(np.asarray(
            list(self.assignments.keys()) + self.unrepaired + self.ghosts,
            dtype=np.int64,
        ))

    def summary(self) -> dict:
        return {
            "rows_repaired": self.rows_repaired,
            "permissive_repairs": len(self.permissive),
            "best_effort_repairs": len(self.best_effort),
            "disabled": len(self.disabled),
            "ghosts": len(self.ghosts),
            "unrepaired": len(self.unrepaired),
            "spares_used": self.spares_used,
            "spares_left": self.spares_left,
            "degraded": self.degraded,
        }


def row_utilization(layout: TCAMLayout, xbits: np.ndarray) -> np.ndarray:
    """(R,) hit counts: how many encoded inputs each row serves (first
    surviving row wins, matching the engine's argmax).  Use on the *ideal*
    layout with training data to prioritize repair of heavy rules."""
    xpad = layout.pad_inputs(np.asarray(xbits, np.uint8))
    m = row_match(layout.cells, xpad, 1 + layout.width)      # (R, B)
    hit = m.any(axis=0)
    first = np.argmax(m, axis=0)
    return np.bincount(first[hit], minlength=layout.cells.shape[0])


def _mask_rows(mask: SAFMask, idx: np.ndarray) -> SAFMask:
    return SAFMask(
        sa0_r1=mask.sa0_r1[idx], sa1_r1=mask.sa1_r1[idx],
        sa0_r2=mask.sa0_r2[idx], sa1_r2=mask.sa1_r2[idx],
    )


def _max_matching(adj: list[list[int]]) -> dict[int, int]:
    """Kuhn's augmenting-path maximum bipartite matching.

    ``adj[i]`` lists candidate spare positions for row position ``i`` in
    preference order (clean before permissive).  Rows are offered in input
    order, so higher-priority rows get first claim on scarce spares.
    Returns ``{row position: spare position}``."""
    match: dict[int, int] = {}        # spare position -> row position

    def aug(i: int, seen: set) -> bool:
        for j in adj[i]:
            if j in seen:
                continue
            seen.add(j)
            if j not in match or aug(match[j], seen):
                match[j] = i
                return True
        return False

    for i in range(len(adj)):
        aug(i, set())
    return {i: j for j, i in match.items()}


def _disable_row(
    intent: np.ndarray, mask: SAFMask, row: int, used: int
) -> bool:
    """Silence one physical row in place; True on success.

    Primary: write '1' into the decoder cell (queries carry '0' there).
    Fallback: poison-write CELL_MM into the first body cell whose two
    elements are both free of stuck-at-HRS (a full {LRS,LRS} write needs
    both elements to reach LRS)."""
    intent[row, 0] = CELL_1
    actual = apply_saf_mask(intent[row][None, :], _mask_rows(mask, [row]))
    if row_signatures(actual, used)[0][0]:
        return True
    for c in range(1, used):
        if not (mask.sa0_r1[row, c] or mask.sa0_r2[row, c]):
            intent[row, c] = CELL_MM
            return True
    return False


def repair_layout(
    layout: TCAMLayout,
    intent_cells: np.ndarray,
    mask: SAFMask,
    defect_rows: np.ndarray,
    *,
    allow_permissive: bool = True,
    priority: Optional[np.ndarray] = None,
) -> tuple[TCAMLayout, np.ndarray, RepairReport]:
    """Remap defective rows onto write-verified spares.

    layout: the chip as it currently responds (``cells`` already faulted).
    intent_cells: the content the controller programmed (ideal initially).
    mask: the chip's persistent stuck-element state.
    defect_rows: physical row indices flagged by BIST.
    priority: optional per-row score — higher repaired first (defaults to
        row order, i.e. LUT priority order).

    Returns ``(new_layout, new_intent, report)``; ``new_layout.cells`` is
    the post-repair chip response (``apply_saf_mask(new_intent, mask)``).
    """
    used = 1 + layout.width
    intent = np.array(intent_cells, copy=True)
    classes = np.array(layout.classes, copy=True)
    class_bits = np.array(layout.class_bits, copy=True)
    defect_rows = np.asarray(defect_rows, dtype=int)

    # free spares: rogue rows still programmed to their pristine dead intent
    spare_idx = layout.spare_row_indices
    free = [int(j) for j in spare_idx if intent[j, 0] == CELL_1]

    # defective LUT rows whose intent is still an alive rule
    dead_i = row_signatures(intent, used)[0]
    todo = [int(r) for r in defect_rows if r < layout.n_rows and not dead_i[r]]
    if priority is not None:
        todo.sort(key=lambda r: -float(priority[r]))

    assignments: dict[int, int] = {}
    permissive_rows: list[int] = []
    best_effort_rows: list[int] = []
    disabled: list[int] = []
    ghosts: list[int] = []
    unrepaired: list[int] = []

    n_t, n_s = len(todo), len(free)
    if n_t and n_s:
        todo_arr = np.asarray(todo)
        j_arr = np.asarray(free)
        spare_masks = _mask_rows(mask, j_arr)

        # write-verify every (row, spare) pair: grade 2 = clean copy,
        # 1 = permissive-only, 0 = damaged; damage = # misbehaving literals
        CLEAN, PERM = 2, 1
        grade = np.zeros((n_t, n_s), np.int8)
        damage = np.full((n_t, n_s), np.inf)
        _, zi_t, oi_t = row_signatures(intent[todo_arr], used)
        for i, r in enumerate(todo):
            written = apply_saf_mask(
                np.repeat(intent[r][None, :], n_s, axis=0), spare_masks
            )
            d, z, o = row_signatures(written, used)
            zi, oi = zi_t[i], oi_t[i]
            lit_diff = (z != zi).sum(axis=1) + (o != oi).sum(axis=1)
            perm = ~d & ~(z & ~zi).any(axis=1) & ~(o & ~oi).any(axis=1)
            grade[i, ~d & (lit_diff == 0)] = CLEAN
            grade[i, perm & (grade[i] != CLEAN)] = PERM
            damage[i] = np.where(d, np.inf, lit_diff)

        # how badly does the *unrepaired original* already misbehave?
        da, za, oa = row_signatures(layout.cells[todo_arr], used)
        orig_damage = np.where(
            da, used + 1,
            (za != zi_t).sum(axis=1) + (oa != oi_t).sum(axis=1),
        )

        adj = []
        for i in range(n_t):
            cl = np.flatnonzero(grade[i] == CLEAN).tolist()
            pm = (np.flatnonzero(grade[i] == PERM).tolist()
                  if allow_permissive else [])
            adj.append(cl + pm)
        row2spare = _max_matching(adj)

        taken = set(row2spare.values())
        for i, r in enumerate(todo):
            pick = row2spare.get(i)
            kind = None
            if pick is not None:
                kind = "perm" if grade[i, pick] < CLEAN else "clean"
            elif allow_permissive:
                # best-effort: least-damaged leftover spare, only if it
                # misbehaves on strictly fewer literals than the original
                open_pos = [s for s in range(n_s) if s not in taken]
                if open_pos:
                    s = min(open_pos, key=lambda s: damage[i, s])
                    if damage[i, s] < orig_damage[i]:
                        pick, kind = s, "best_effort"
            if pick is None:
                unrepaired.append(r)
                continue
            taken.add(pick)
            j = int(j_arr[pick])
            intent[j] = intent[r]
            assignments[r] = j
            if kind == "perm":
                permissive_rows.append(j)
            elif kind == "best_effort":
                best_effort_rows.append(j)
            classes[j] = classes[r]
            class_bits[j] = class_bits[r]
            if _disable_row(intent, mask, r, used):
                disabled.append(r)
            else:
                ghosts.append(r)
        free = [int(j_arr[s]) for s in range(n_s) if s not in taken]
    else:
        unrepaired.extend(todo)

    # ghost spares: rogue rows that BIST caught responding despite a dead
    # intent — silence them so they cannot steal queries with random classes
    for r in defect_rows:
        r = int(r)
        if r >= layout.n_rows and r not in assignments.values():
            if not _disable_row(intent, mask, r, used):
                ghosts.append(r)

    new_cells = apply_saf_mask(intent, mask)
    # padding columns beyond decoder+LUT width are OFF-OFF (masked) — faults
    # there never reach the match line; keep the served grid don't-care
    new_cells[:, used:] = CELL_X
    new_layout = dataclasses.replace(
        layout, cells=new_cells, classes=classes, class_bits=class_bits
    )
    report = RepairReport(
        assignments=assignments,
        permissive=permissive_rows,
        best_effort=best_effort_rows,
        disabled=disabled,
        ghosts=ghosts,
        unrepaired=unrepaired,
        spares_used=len(assignments),
        spares_left=len(free),
    )
    return new_layout, intent, report
