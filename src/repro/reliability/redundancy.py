"""N-modular redundancy: majority voting across independently-faulty chips.

``ReplicatedServer`` runs k ``TCAMServer`` instances over the same compiled
model, each with an *independently sampled* chip (its own stuck-at mask and
SA offsets — child generators spawned from one root rng).  Every request
fans out to all k replicas; the result is the majority vote over the replica
predictions, with per-request disagreement surfaced and aggregated.

Independent defects rarely corrupt the same rule on multiple chips, so
majority voting recovers most single-chip errors — the classic TMR argument,
here measurable: ``metrics()['disagreement_rate']`` is a live estimate of
how often redundancy is earning its keep.

Replica failures degrade gracefully: a request's vote is taken over the
replicas that answered; only if *all* replicas fail does the fan-out future
fail (with the first replica's exception).
"""
from __future__ import annotations

import dataclasses
import threading
from concurrent.futures import Future
from typing import Optional, Sequence

import numpy as np

from ..core.compiler import CompiledDT
from ..core.nonideal import IDEAL, NonIdealSpec

__all__ = ["VotedResult", "ReplicatedServer", "majority_vote"]


def majority_vote(votes: Sequence[int]) -> int:
    """Plurality winner; ties broken toward the smallest class id."""
    counts = np.bincount(np.asarray(votes, dtype=np.int64))
    return int(np.argmax(counts))


@dataclasses.dataclass(frozen=True)
class VotedResult:
    """Fan-out outcome: the voted decision plus per-replica detail."""

    prediction: int
    votes: tuple              # per-replica predicted class (None = failed)
    n_replicas: int
    n_answered: int
    n_agree: int              # replicas that voted with the majority
    results: tuple            # per-replica RequestResult (None = failed)

    @property
    def unanimous(self) -> bool:
        return self.n_agree == self.n_answered

    @property
    def disagreement(self) -> bool:
        return self.n_answered > 0 and not self.unanimous


class ReplicatedServer:
    """k-modular-redundant front door over ``TCAMServer`` replicas.

    >>> rs = ReplicatedServer(model.compiled, k=3,
    ...                       nonideal=NonIdealSpec(p_sa0=0.02, p_sa1=0.02))
    >>> rs.submit(x).result().prediction       # majority of 3 chips
    >>> rs.metrics()["disagreement_rate"]
    >>> rs.close()
    """

    def __init__(
        self,
        compiled: CompiledDT,
        k: int = 3,
        *,
        nonideal: NonIdealSpec = IDEAL,
        rng: Optional[np.random.Generator] = None,
        **server_kwargs,
    ) -> None:
        if k < 1:
            raise ValueError("k must be >= 1")
        from ..serve.engine import TCAMServer  # lazy: avoid import cycle

        rng = rng if rng is not None else np.random.default_rng(0)
        self.replicas = [
            TCAMServer(compiled, nonideal=nonideal, rng=child, **server_kwargs)
            for child in rng.spawn(k)
        ]
        self._lock = threading.Lock()
        self.requests = 0
        self.disagreements = 0
        self.replica_failures = 0
        self.agree_sum = 0
        self.answered_sum = 0

    @property
    def k(self) -> int:
        return len(self.replicas)

    # -- request fan-out ---------------------------------------------------
    def submit(self, x: np.ndarray) -> Future:
        out: Future = Future()
        parts = [r.submit(x) for r in self.replicas]
        pending = [len(parts)]
        plock = threading.Lock()

        def on_done(_f) -> None:
            with plock:
                pending[0] -= 1
                if pending[0]:
                    return
            self._combine(parts, out)

        for f in parts:
            f.add_done_callback(on_done)
        return out

    def _combine(self, parts: list, out: Future) -> None:
        results = [None if f.exception() is not None else f.result()
                   for f in parts]
        votes = [r.prediction if r is not None else None for r in results]
        answered = [v for v in votes if v is not None]
        n_failed = len(votes) - len(answered)
        with self._lock:
            self.requests += 1
            self.replica_failures += n_failed
        if not answered:
            out.set_exception(next(f.exception() for f in parts
                                   if f.exception() is not None))
            return
        winner = majority_vote(answered)
        n_agree = sum(v == winner for v in answered)
        with self._lock:
            self.answered_sum += len(answered)
            self.agree_sum += n_agree
            if n_agree != len(answered):
                self.disagreements += 1
        out.set_result(VotedResult(
            prediction=winner,
            votes=tuple(votes),
            n_replicas=len(votes),
            n_answered=len(answered),
            n_agree=n_agree,
            results=tuple(results),
        ))

    def submit_many(self, X: np.ndarray) -> list[Future]:
        return [self.submit(row) for row in np.asarray(X)]

    def serve(self, X: np.ndarray) -> list[VotedResult]:
        futs = self.submit_many(X)
        self.drain()
        return [f.result() for f in futs]

    # -- lifecycle & metrics ----------------------------------------------
    def drain(self, timeout: Optional[float] = None) -> None:
        for r in self.replicas:
            r.drain(timeout)

    def metrics(self) -> dict:
        with self._lock:
            reqs = self.requests
            out = {
                "k": self.k,
                "requests": reqs,
                "disagreements": self.disagreements,
                "disagreement_rate": (
                    self.disagreements / reqs if reqs else 0.0
                ),
                "mean_agreement": (
                    self.agree_sum / self.answered_sum
                    if self.answered_sum else float("nan")
                ),
                "replica_failures": self.replica_failures,
            }
        out["replicas"] = [r.metrics() for r in self.replicas]
        out["health"] = [r.health() for r in self.replicas]
        return out

    def close(self) -> None:
        for r in self.replicas:
            r.close()

    def __enter__(self) -> "ReplicatedServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
