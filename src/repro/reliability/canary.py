"""Golden-vector canary probes and the serving circuit breaker.

A canary is a tiny fixed query set with *known-good* answers, replayed
periodically through the production compute path.  Golden vectors are
synthesized from the ideal layout (one matching word per LUT row, don't-care
positions filled randomly) and labelled by evaluating the *ideal* chip — no
dataset required at serving time.

``CircuitBreaker`` tracks the chip-health state machine the server drives:

    HEALTHY --canary below threshold--> DEGRADED
    DEGRADED --drift scrub + refresh + canary re-vote ok--> REPAIRED
    DEGRADED --BIST + spare-row repair + canary re-vote ok--> REPAIRED
    DEGRADED/REPAIRED --repair insufficient, 'ref' engine canary ok--> FALLBACK
    otherwise --> FAILED   (still serving, loudly degraded)
    REPAIRED --routine canary re-pass--> HEALTHY   (re-enters steady state)

The breaker never opens the request path — a degraded chip keeps answering
(the paper's whole point is graceful accuracy degradation); the state is
surfaced through ``TCAMServer.health()`` and the metrics snapshot so
operators and the ReplicatedServer can react.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ..core.lut import CELL_X
from ..core.synth import TCAMLayout
from .bist import march_probes, row_match

__all__ = ["BreakerState", "CanaryProbe", "CircuitBreaker", "make_canary"]


class BreakerState:
    HEALTHY = "healthy"
    DEGRADED = "degraded"
    REPAIRED = "repaired"
    FALLBACK = "fallback"
    FAILED = "failed"


@dataclasses.dataclass(frozen=True)
class CanaryProbe:
    """Golden vectors at the search-word level: (n, W) padded words plus the
    ideal chip's predictions for them."""

    words: np.ndarray
    expected: np.ndarray

    def __len__(self) -> int:
        return int(self.words.shape[0])

    def accuracy(self, predictions: np.ndarray) -> float:
        return float(
            (np.asarray(predictions) == self.expected).mean()
        )


def make_canary(
    layout: TCAMLayout,
    n: int,
    rng: np.random.Generator,
) -> CanaryProbe:
    """Synthesize golden vectors from an ideal layout.

    Each vector is a LUT row's matching word with its don't-care positions
    filled from ``rng`` (so the canary also exercises bits the row ignores);
    expected labels come from evaluating the ideal layout itself, so a
    canary miss always means the serving chip diverged from the ideal chip.
    """
    used = 1 + layout.width
    w = layout.cells.shape[1]
    rows = rng.choice(
        np.arange(layout.n_rows), size=n, replace=n > layout.n_rows
    )
    words = np.zeros((n, w), np.uint8)
    for i, r in enumerate(rows):
        base = march_probes(layout.cells[r], used)[0]
        xmask = layout.cells[r, 1:used] == CELL_X     # don't-care positions
        fill = rng.integers(0, 2, size=int(xmask.sum())).astype(np.uint8)
        base[1:used][xmask] = fill
        words[i] = base
    m = row_match(layout.cells, words, used)          # (R, n)
    hit = m.any(axis=0)
    first = np.argmax(m, axis=0)
    expected = np.where(
        hit, layout.classes[first], 0
    ).astype(np.int32)
    return CanaryProbe(words=words, expected=expected)


@dataclasses.dataclass
class CircuitBreaker:
    """Chip-health state machine fed by canary accuracies."""

    threshold: float = 0.9
    state: str = BreakerState.HEALTHY
    trips: int = 0
    last_accuracy: float = float("nan")
    recovery: Optional[str] = None     # 'scrub' | 'repair' | 'fallback_ref'

    def observe(self, accuracy: float) -> bool:
        """Record a routine canary run; True iff the breaker trips (healthy
        or recovered state and accuracy below threshold)."""
        self.last_accuracy = accuracy
        if accuracy >= self.threshold:
            if self.state in (BreakerState.DEGRADED, BreakerState.FAILED,
                              BreakerState.REPAIRED):
                # DEGRADED/FAILED: chip spontaneously back above threshold;
                # REPAIRED: a routine canary re-passed after recovery, so the
                # chip re-enters steady state.  FALLBACK stays sticky — its
                # canaries pass *on the fallback engine*, which says nothing
                # about the primary path.
                self.state = BreakerState.HEALTHY
            return False
        if self.state in (BreakerState.HEALTHY, BreakerState.REPAIRED,
                          BreakerState.FALLBACK):
            self.state = BreakerState.DEGRADED
            self.trips += 1
            return True
        return self.state == BreakerState.DEGRADED

    def recovered(self, how: str, accuracy: float) -> None:
        """A recovery rung re-passed the canary: 'scrub' (drift refresh) and
        'repair' (spare-row remap) restore full-fidelity serving (REPAIRED);
        anything else is a degraded-but-serving fallback (FALLBACK)."""
        self.last_accuracy = accuracy
        self.recovery = how
        self.state = (
            BreakerState.REPAIRED if how in ("scrub", "repair")
            else BreakerState.FALLBACK
        )

    def failed(self, accuracy: float) -> None:
        self.last_accuracy = accuracy
        self.state = BreakerState.FAILED

    def snapshot(self) -> dict:
        return {
            "state": self.state,
            "trips": self.trips,
            "threshold": self.threshold,
            "last_accuracy": self.last_accuracy,
            "recovery": self.recovery,
        }
