"""March-style built-in self-test (BIST) for TCAM arrays.

A deployed chip cannot be read cell-by-cell; its only observable is the
match/mismatch response to search words.  The BIST therefore probes each
physical row with a small synthesized test set and compares the *observed*
response against the *intended* response (what the row was programmed to
hold), in the spirit of march tests for CAMs:

  M0 (stored-word element): the row's own matching word — every intended
      literal satisfied.  A healthy row matches; a row with any restrictive
      fault (``X -> 0/1`` flip, ``{LRS,LRS}`` always-mismatch cell, decoder
      corruption) responds differently from intent.
  M1 (walking-bit element): flip one body bit of M0 at a time.  A healthy
      row mismatches exactly at its literal positions; a permissive fault
      (``0/1 -> X``) matches where it should not, a flipped literal
      mismatches where it should not.
  M2/M3 (readback elements): the same two probe families synthesized from
      the *observed* cell state (2T2R cells are resistive memory with a read
      port — readback is how a controller verifies writes).  Intent-derived
      probes alone can miss a row whose intent is dead but whose faults
      brought it alive with several 1-literals: no single walking bit
      satisfies all of them at once.  The actual row's own characteristic
      word does, exposing the rogue.

The decoder bit (column 0) is held at the query value '0' throughout —
probes only cover inputs the chip can actually see, so rows whose faults are
behaviorally invisible to real queries are (correctly) not flagged.

``row_signatures`` / ``behavior_changed_rows`` give the analytic ground
truth — two rows respond identically to every reachable query iff their
(dead?, 0-literal set, 1-literal set) signatures agree — used for coverage
accounting in tests and the chaos harness.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.lut import CELL_0, CELL_1, CELL_MM, CELL_X
from ..core.synth import TCAMLayout

__all__ = [
    "BistReport", "march_probes", "row_match", "row_signatures",
    "behavior_changed_rows", "run_bist",
]


def row_match(cells: np.ndarray, words: np.ndarray, used: int) -> np.ndarray:
    """Evaluate search words against rows of cells; (R, P) or (P,) booleans.

    A row survives iff every unmasked cell (columns ``[0, used)``) matches:
    CELL_X matches both bits, CELL_0/1 match their bit, CELL_MM matches
    neither.  Columns beyond ``used`` are masked (OFF-OFF) and ignored —
    identical to the oracle's final survive with kmax=0.
    """
    cells = np.atleast_2d(np.asarray(cells))[:, :used]       # (R, used)
    words = np.atleast_2d(np.asarray(words))[:, :used]       # (P, used)
    c = cells[:, None, :]                                    # (R, 1, used)
    w = words[None, :, :]                                    # (1, P, used)
    ok = ((c == CELL_X) | ((c == CELL_0) & (w == 0))
          | ((c == CELL_1) & (w == 1)))
    return ok.all(axis=2)                                    # (R, P)


def row_signatures(
    cells: np.ndarray, used: int
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Analytic behavior signature of each row over *reachable* queries
    (decoder bit fixed at 0, body bits free).

    Returns ``(dead, zeros, ones)``: ``dead`` (R,) — the row matches no
    reachable query; ``zeros``/``ones`` (R, used-1) — body positions whose
    input bit must be 0 / must be 1.  Two alive rows behave identically iff
    their literal sets agree; literal masks of dead rows are meaningless.
    """
    cells = np.atleast_2d(np.asarray(cells))
    dec = cells[:, 0]
    body = cells[:, 1:used]
    dead = np.isin(dec, (CELL_1, CELL_MM)) | (body == CELL_MM).any(axis=1)
    return dead, body == CELL_0, body == CELL_1


def behavior_changed_rows(
    intent_cells: np.ndarray, actual_cells: np.ndarray, used: int
) -> np.ndarray:
    """(R,) bool — rows whose faults change the match response to at least
    one reachable query (the ground truth a BIST run is scored against)."""
    di, zi, oi = row_signatures(intent_cells, used)
    da, za, oa = row_signatures(actual_cells, used)
    alive_diff = (
        ~di & ~da & ((zi != za).any(axis=1) | (oi != oa).any(axis=1))
    )
    return (di != da) | alive_diff


def march_probes(intent_row: np.ndarray, used: int) -> np.ndarray:
    """Synthesize the M0 + M1 probe set for one row's intended content.

    (used, W) uint8: row 0 is the stored word (decoder 0, CELL_1 -> 1, else
    0), rows 1.. walk a single flipped bit across the body columns.
    """
    intent_row = np.asarray(intent_row)
    w = intent_row.shape[0]
    base = np.zeros(w, np.uint8)
    base[:used] = (intent_row[:used] == CELL_1).astype(np.uint8)
    base[0] = 0                                   # decoder query bit is fixed
    probes = np.tile(base, (used, 1))
    flip = np.arange(1, used)                     # M1: walk the body bits
    probes[1 + np.arange(used - 1), flip] ^= 1
    return probes


@dataclasses.dataclass
class BistReport:
    """Per-row defect map from one self-test pass."""

    detected: np.ndarray          # (R,) bool — observed response != intent
    probes_run: int
    n_rows: int                   # LUT (non-spare) rows in the array

    @property
    def defective_rows(self) -> np.ndarray:
        return np.flatnonzero(self.detected)

    @property
    def n_defective(self) -> int:
        return int(self.detected.sum())

    def coverage(self, changed: np.ndarray) -> float:
        """Fraction of ground-truth behavior-changing rows detected
        (1.0 when nothing changed)."""
        changed = np.asarray(changed, bool)
        if not changed.any():
            return 1.0
        return float((self.detected & changed).sum() / changed.sum())

    def summary(self) -> dict:
        return {
            "rows": int(self.detected.size),
            "lut_rows": self.n_rows,
            "defective": self.n_defective,
            "defective_lut_rows": int(self.detected[: self.n_rows].sum()),
            "probes_run": self.probes_run,
        }


def run_bist(
    actual_cells: np.ndarray,
    intent_cells: np.ndarray,
    *,
    used: int,
    n_rows: int,
) -> BistReport:
    """Self-test every physical row of a chip against its intended content.

    ``actual_cells`` is the faulty array as it responds on-chip,
    ``intent_cells`` the content the controller programmed (the ideal layout
    initially; updated by repair).  ``used = 1 + lut_width`` unmasked
    columns; ``n_rows`` LUT rows (the rest are rogue/spare rows whose intent
    is to never match).
    """
    actual_cells = np.asarray(actual_cells)
    intent_cells = np.asarray(intent_cells)
    if actual_cells.shape != intent_cells.shape:
        raise ValueError("actual/intent cell grids must have the same shape")
    r = actual_cells.shape[0]
    detected = np.zeros(r, bool)
    probes_run = 0
    for i in range(r):
        probes = march_probes(intent_cells[i], used)         # M0 + M1
        readback = march_probes(actual_cells[i], used)       # M2 + M3
        if (readback != probes).any():
            probes = np.concatenate([probes, readback])
        probes_run += probes.shape[0]
        expect = row_match(intent_cells[i], probes, used)[0]
        got = row_match(actual_cells[i], probes, used)[0]
        detected[i] = bool((expect != got).any())
    return BistReport(detected=detected, probes_run=probes_run, n_rows=n_rows)


def bist_layout(layout: TCAMLayout, intent_cells: np.ndarray) -> BistReport:
    """Convenience wrapper: self-test a layout's cells against intent."""
    return run_bist(
        layout.cells, intent_cells,
        used=1 + layout.width, n_rows=layout.n_rows,
    )
