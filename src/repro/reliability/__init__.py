"""Chip-health & fault-tolerant serving for DT2CAM TCAM arrays.

The paper's robustness claim (§II.C, Fig 7/8) is that accuracy *degrades
gracefully* under stuck-at faults, SA variability, and input noise.  This
package adds the mechanisms a real analog-CAM deployment layers on top of
that raw tolerance (cf. Pedretti et al.'s defect-aware mapping):

  bist.py        — march-style built-in self-test: probe the physical array
                   with synthesized test words, emit a per-row defect map.
  repair.py      — spare-row repair: remap defective rows onto the rogue-row
                   spare pool with write-verification through the chip's
                   stuck-element mask; graceful-degradation reporting.
  redundancy.py  — ReplicatedServer: N-modular redundancy across
                   independently-sampled chip instances, majority voting,
                   disagreement metrics.
  canary.py      — golden-vector canary probes + circuit breaker driving the
                   degradation ladder (degraded -> repair -> re-vote ->
                   engine fallback).

``serve.TCAMServer`` wires these together: ``self_test()``, ``repair()``,
``run_canary()`` and a periodic canary that trips the breaker automatically.
"""
from .bist import (
    BistReport,
    behavior_changed_rows,
    march_probes,
    row_match,
    row_signatures,
    run_bist,
)
from .canary import BreakerState, CanaryProbe, CircuitBreaker, make_canary
from .redundancy import ReplicatedServer, VotedResult, majority_vote
from .repair import RepairReport, repair_layout, row_utilization

__all__ = [
    "BistReport", "behavior_changed_rows", "march_probes", "row_match",
    "row_signatures", "run_bist",
    "BreakerState", "CanaryProbe", "CircuitBreaker", "make_canary",
    "ReplicatedServer", "VotedResult", "majority_vote",
    "RepairReport", "repair_layout", "row_utilization",
]
