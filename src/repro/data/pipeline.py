"""Deterministic synthetic LM data pipeline.

Design goals (1000+ node posture):
  * **Resumable by construction** — every batch is a pure function of
    ``(seed, step)``; restoring a checkpoint at step k reproduces the exact
    stream with no iterator state to persist.
  * **Shard-aware** — ``batch_at(step, shard, n_shards)`` yields only the
    host's slice of the global batch, identical to what a global batch
    sharded over hosts would contain.
  * **Learnable** — tokens follow a planted successor recurrence
    (t_{i+1} = (t_i + c) mod V with segment resets and noise) that a small
    LM learns within tens of steps, so training losses drop measurably and
    loss curves are comparable across runs/configs.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..models.config import ModelConfig

__all__ = ["TokenPipeline", "make_batch"]


@dataclasses.dataclass
class TokenPipeline:
    cfg: ModelConfig
    global_batch: int
    seq_len: int
    seed: int = 0
    noise: float = 0.02

    def batch_at(self, step: int, shard: int = 0, n_shards: int = 1) -> dict:
        assert self.global_batch % n_shards == 0
        b = self.global_batch // n_shards
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, shard]))
        v = self.cfg.vocab_size
        text = self.seq_len - self.cfg.frontend_tokens
        # per-sequence stride c: the model must learn t -> (t + c) mod V
        # conditioned on the sequence's early tokens
        c = rng.integers(1, min(v, 17), size=(b, 1))
        i_idx = np.arange(text + 1)[None, :]
        start = rng.integers(0, v, size=(b, 1))
        toks = (start + c * i_idx) % v
        # segment resets + token noise keep entropy bounded away from zero
        resets = rng.random((b, text + 1)) < 1.0 / 256
        toks[resets] = rng.integers(0, v, size=int(resets.sum()))
        noise = rng.random((b, text + 1)) < self.noise
        toks[noise] = rng.integers(0, v, size=int(noise.sum()))
        batch = {
            "tokens": toks[:, :text].astype(np.int32),
            "labels": toks[:, 1: text + 1].astype(np.int32),
        }
        if self.cfg.frontend_tokens:
            batch["patches"] = rng.standard_normal(
                (b, self.cfg.frontend_tokens, self.cfg.d_model),
                dtype=np.float32) * 0.02
        if self.cfg.is_encdec:
            batch["frames"] = rng.standard_normal(
                (b, self.cfg.encoder_seq, self.cfg.d_model),
                dtype=np.float32) * 0.02
        return batch


def make_batch(cfg: ModelConfig, batch: int, seq: int, step: int = 0,
               seed: int = 0) -> dict:
    return TokenPipeline(cfg, batch, seq, seed=seed).batch_at(step)
