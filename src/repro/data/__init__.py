"""Deterministic, resumable synthetic token pipeline."""
from .pipeline import TokenPipeline, make_batch

__all__ = ["TokenPipeline", "make_batch"]
