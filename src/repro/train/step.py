"""Step builders: jit-compiled train / prefill / decode steps with full
sharding specifications, microbatch gradient accumulation, remat policies and
optional int8+EF gradient compression.

``input_specs`` produces ShapeDtypeStruct stand-ins (sharding attached) for
every model input of every (arch × shape) cell — the multi-pod dry-run
lowers/compiles against these without allocating anything.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import (
    ModelConfig, decode_step, init_cache, init_params, loss_fn,
    param_logical_axes, prefill,
)
from ..models.layers import COMPUTE_DTYPE
from ..optim import (
    AdamWConfig, OptState, adamw_init, adamw_update, ef_compress,
)
from ..optim.compress import ef_init
from ..sharding import Rules, make_rules, use_rules

__all__ = [
    "TrainState", "init_train_state", "state_shardings", "input_specs",
    "build_train_step", "build_prefill_step", "build_decode_step",
    "cache_logical_axes",
]


class TrainState(NamedTuple):
    params: dict
    opt: OptState
    ef: Optional[dict]      # error-feedback residual (compression) or None


def init_train_state(cfg: ModelConfig, key, *, compress: bool = False,
                     opt_cfg: AdamWConfig = AdamWConfig()) -> TrainState:
    params = init_params(cfg, key)
    return TrainState(params=params, opt=adamw_init(params, opt_cfg),
                      ef=ef_init(params) if compress else None)


# ---------------------------------------------------------------------------
# sharding specs
# ---------------------------------------------------------------------------
def _param_shardings(cfg: ModelConfig, rules: Rules, shapes) -> Any:
    axes = param_logical_axes(cfg)
    return jax.tree.map(
        lambda ax, s: rules.sharding(ax, s.shape), axes, shapes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x),
    )


def state_shardings(cfg: ModelConfig, rules: Rules, *,
                    compress: bool = False, dtype=jnp.float32,
                    mu_dtype=jnp.float32, nu_dtype=jnp.float32):
    """ShapeDtypeStructs (shardings attached) for the full TrainState."""
    key = jax.random.PRNGKey(0)
    p_shapes = jax.eval_shape(lambda: init_params(cfg, key))
    p_shard = _param_shardings(cfg, rules, p_shapes)

    def sds(shape_tree, shard_tree, dt=None):
        return jax.tree.map(
            lambda s, sh: jax.ShapeDtypeStruct(
                s.shape, dt or s.dtype, sharding=sh),
            shape_tree, shard_tree)

    params_sds = sds(p_shapes, p_shard, dtype)
    cast = lambda tree, dt: jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dt, sharding=s.sharding),
        tree)
    opt_sds = OptState(mu=cast(params_sds, mu_dtype),
                       nu=cast(params_sds, nu_dtype),
                       step=jax.ShapeDtypeStruct((), jnp.int32))
    ef_sds = cast(params_sds, jnp.float32) if compress else None
    return TrainState(params=params_sds, opt=opt_sds, ef=ef_sds)


def cache_logical_axes(cfg: ModelConfig) -> dict:
    """Logical axes mirroring ``init_cache``'s structure."""
    table = {
        "k": ("layers", "act_batch", "cache_seq", "act_kv_heads", "act_hd"),
        "v": ("layers", "act_batch", "cache_seq", "act_kv_heads", "act_hd"),
        "slot_pos": ("layers", None),
        "conv": ("layers", "act_batch", None, "act_dinner"),
        "h": ("layers", "act_batch", "act_dinner", None),
        "xa": ("layers", "act_batch", None),
        "S": ("layers", "act_batch", None, None, None),
        "xc": ("layers", "act_batch", None),
        "xk": ("layers", "act_batch", None, "act_heads", None),
        "xv": ("layers", "act_batch", None, "act_heads", None),
    }
    out = {}
    for kind in cfg.kinds:
        mixer, ffn = kind.split("+")
        names = []
        if mixer in ("attn", "swa"):
            names += ["k", "v", "slot_pos"]
        elif mixer == "mamba":
            names += ["conv", "h"]
        elif mixer == "rwkv":
            names += ["xa", "S", "xc"]
        if ffn == "cmix" and "xc" not in names:
            names.append("xc")
        if cfg.is_encdec:
            names += ["xk", "xv"]
        out[kind] = {n: table[n] for n in names}
    return out


def _batch_struct(cfg: ModelConfig, seq: int, batch: int, rules: Rules,
                  *, with_labels: bool):
    bsp = rules.sharding(("act_batch", None), (batch, seq))
    text = seq - cfg.frontend_tokens
    out = {
        "tokens": jax.ShapeDtypeStruct((batch, text), jnp.int32, sharding=bsp),
    }
    if with_labels:
        # labels align with text positions; loss_fn pads frontend positions
        out["labels"] = jax.ShapeDtypeStruct((batch, text), jnp.int32,
                                             sharding=bsp)
    if cfg.frontend_tokens:
        shp = (batch, cfg.frontend_tokens, cfg.d_model)
        out["patches"] = jax.ShapeDtypeStruct(
            shp, COMPUTE_DTYPE,
            sharding=rules.sharding(("act_batch", None, None), shp))
    if cfg.is_encdec:
        shp = (batch, cfg.encoder_seq, cfg.d_model)
        out["frames"] = jax.ShapeDtypeStruct(
            shp, COMPUTE_DTYPE,
            sharding=rules.sharding(("act_batch", None, None), shp))
    return out


def _cache_struct(cfg: ModelConfig, batch: int, max_seq: int, rules: Rules):
    shapes = jax.eval_shape(
        functools.partial(init_cache, cfg, batch, max_seq))
    axes = cache_logical_axes(cfg)
    return jax.tree.map(
        lambda s, ax: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=rules.sharding(ax, s.shape)),
        shapes, axes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x))


def input_specs(cfg: ModelConfig, shape, rules: Rules,
                settings: Optional[dict] = None) -> dict:
    """ShapeDtypeStruct stand-ins for one (arch × shape) cell.

    shape.step selects the lowered computation:
      train   -> {"state": TrainState, "batch": {...}}
      prefill -> {"params", "batch", "caches"}
      decode  -> {"params", "token", "caches", "pos"}
    """
    settings = settings or {}
    b, s = shape.global_batch, shape.seq_len
    if shape.step == "train":
        state = state_shardings(
            cfg, rules,
            dtype=jnp.dtype(settings.get("param_dtype", "float32")),
            mu_dtype=jnp.dtype(settings.get("mu_dtype", "float32")),
            nu_dtype=jnp.dtype(settings.get("nu_dtype", "float32")))
        batch = _batch_struct(cfg, s, b, rules, with_labels=True)
        return {"state": state, "batch": batch}
    if shape.step == "prefill":
        state = state_shardings(cfg, rules, dtype=COMPUTE_DTYPE)
        batch = _batch_struct(cfg, s, b, rules, with_labels=False)
        caches = _cache_struct(cfg, b, s, rules)
        return {"params": state.params, "batch": batch, "caches": caches}
    if shape.step == "decode":
        state = state_shardings(cfg, rules, dtype=COMPUTE_DTYPE)
        token = jax.ShapeDtypeStruct(
            (b, 1), jnp.int32, sharding=rules.sharding(("act_batch", None)))
        caches = _cache_struct(cfg, b, s, rules)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        return {"params": state.params, "token": token, "caches": caches,
                "pos": pos}
    raise ValueError(shape.step)


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------
def build_train_step(
    cfg: ModelConfig,
    rules: Rules,
    opt_cfg: AdamWConfig = AdamWConfig(),
    *,
    accum: int = 1,
    compress: bool = False,
    remat: str = "full",
    accum_dtype=jnp.float32,
):
    """Returns train_step(state, batch) -> (state, metrics), jit-ready.

    accum > 1 splits the per-step batch into microbatches scanned
    sequentially; XLA's latency-hiding scheduler overlaps microbatch i+1's
    compute with microbatch i's gradient reduce-scatter on real meshes.
    ``accum_dtype`` controls the accumulation buffer (bf16 halves the
    gradient HBM for 100B+ models; see configs.TRAIN_SETTINGS).
    """

    def loss_of(params, mb):
        return loss_fn(params, cfg, mb, remat=remat)

    def train_step(state: TrainState, batch):
        with use_rules(rules):
            if accum == 1:
                (loss, metrics), grads = jax.value_and_grad(
                    loss_of, has_aux=True)(state.params, batch)
            else:
                def split(x):
                    return x.reshape(accum, x.shape[0] // accum, *x.shape[1:])
                mbs = jax.tree.map(split, batch)

                def micro(carry, mb):
                    g_acc, l_acc = carry
                    (l, _), g = jax.value_and_grad(
                        loss_of, has_aux=True)(state.params, mb)
                    g_acc = jax.tree.map(
                        lambda a, b: a + b.astype(a.dtype), g_acc, g)
                    return (g_acc, l_acc + l), None

                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, accum_dtype), state.params)
                (grads, loss), _ = jax.lax.scan(
                    micro, (zeros, jnp.float32(0)), mbs)
                grads = jax.tree.map(lambda g: g / accum, grads)
                loss = loss / accum
                metrics = {"loss": loss}

            ef = state.ef
            if compress:
                grads, ef = ef_compress(grads, ef)
            params, opt, m2 = adamw_update(grads, state.opt, state.params,
                                           opt_cfg)
            metrics = dict(metrics, **m2)
            return TrainState(params=params, opt=opt, ef=ef), metrics

    return train_step


def build_prefill_step(cfg: ModelConfig, rules: Rules):
    def prefill_step(params, batch, caches):
        with use_rules(rules):
            return prefill(params, cfg, batch["tokens"], caches,
                           frontend=batch.get("patches"),
                           frames=batch.get("frames"))
    return prefill_step


def build_decode_step(cfg: ModelConfig, rules: Rules):
    def serve_step(params, token, caches, pos):
        with use_rules(rules):
            return decode_step(params, cfg, token, caches, pos)
    return serve_step
