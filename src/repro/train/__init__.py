"""Train/serve step builders with full sharding specs."""
from .step import (
    TrainState,
    build_decode_step,
    build_prefill_step,
    build_train_step,
    init_train_state,
    input_specs,
    state_shardings,
)

__all__ = [
    "TrainState", "build_decode_step", "build_prefill_step",
    "build_train_step", "init_train_state", "input_specs", "state_shardings",
]
