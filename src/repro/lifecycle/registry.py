"""Versioned model registry: content-hashed compiled layouts with lineage.

A production deployment retrains continuously; every artifact that can reach
a chip must be addressable, reproducible, and traceable to its parents.  The
registry stores *compiled* models (``CompiledDT`` / ``CompiledForest``) —
the unit the TCAM actually serves — as one ``.npz`` per version plus a JSON
index:

* **content addressing** — the version id is ``<name>:<sha256[:12]>`` over
  every array of the compiled artifact (cells, classes, thresholds, tree
  arrays, ...), so publishing the same compile twice is idempotent and two
  registries agree on identity without coordination;
* **lineage** — each version records its parent version ids (the model it
  was retrained/delta-programmed from) and free-form metadata;
  ``lineage()`` walks the ancestry;
* **round-trip** — ``load()`` reconstructs the full compiled object
  (tree + rule table + LUT + layout, and per-bank proba tables for forests)
  bit-exactly; the lifecycle tests assert array equality and identical
  re-hash.

Everything here is numpy-only; no jax import.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
from typing import Optional, Sequence

import numpy as np

from ..core.cart import DecisionTree
from ..core.compiler import CompiledDT
from ..core.lut import TernaryLUT
from ..core.reduce import RuleTable
from ..core.synth import TCAMLayout

__all__ = ["ModelVersion", "ModelRegistry", "content_hash"]

_INDEX = "index.json"


# ---------------------------------------------------------------------------
# (de)serialization: compiled artifact <-> flat dict of arrays
# ---------------------------------------------------------------------------

def _pack_tree(t: DecisionTree, p: str) -> dict:
    return {
        f"{p}feature": t.feature, f"{p}threshold": t.threshold,
        f"{p}left": t.left, f"{p}right": t.right, f"{p}value": t.value,
        f"{p}n_features": np.int64(t.n_features),
        f"{p}n_classes": np.int64(t.n_classes),
    }


def _unpack_tree(z, p: str) -> DecisionTree:
    return DecisionTree(
        feature=z[f"{p}feature"], threshold=z[f"{p}threshold"],
        left=z[f"{p}left"], right=z[f"{p}right"], value=z[f"{p}value"],
        n_features=int(z[f"{p}n_features"]),
        n_classes=int(z[f"{p}n_classes"]),
    )


def _pack_compiled(c: CompiledDT, p: str = "") -> dict:
    d = _pack_tree(c.tree, f"{p}tree__")
    tb = c.table
    d.update({
        f"{p}tbl__comparator": tb.comparator, f"{p}tbl__th1": tb.th1,
        f"{p}tbl__th2": tb.th2, f"{p}tbl__classes": tb.classes,
        f"{p}tbl__n_classes": np.int64(tb.n_classes),
    })
    lut = c.lut
    d.update({
        f"{p}lut__cells": lut.cells, f"{p}lut__classes": lut.classes,
        f"{p}lut__n_classes": np.int64(lut.n_classes),
        f"{p}lut__feat_offsets": lut.feat_offsets,
        f"{p}lut__n_thresholds": np.int64(len(lut.thresholds)),
    })
    for i, th in enumerate(lut.thresholds):
        d[f"{p}lut__th_{i}"] = th
    lay = c.layout
    d.update({
        f"{p}lay__cells": lay.cells, f"{p}lay__classes": lay.classes,
        f"{p}lay__class_bits": lay.class_bits,
        f"{p}lay__dims": np.asarray(
            [lay.s, lay.n_rwd, lay.n_cwd, lay.n_rows, lay.width,
             lay.n_classes], np.int64),
    })
    return d


def _unpack_compiled(z, p: str = "") -> CompiledDT:
    tree = _unpack_tree(z, f"{p}tree__")
    table = RuleTable(
        comparator=z[f"{p}tbl__comparator"], th1=z[f"{p}tbl__th1"],
        th2=z[f"{p}tbl__th2"], classes=z[f"{p}tbl__classes"],
        n_classes=int(z[f"{p}tbl__n_classes"]),
    )
    n_th = int(z[f"{p}lut__n_thresholds"])
    lut = TernaryLUT(
        cells=z[f"{p}lut__cells"], classes=z[f"{p}lut__classes"],
        n_classes=int(z[f"{p}lut__n_classes"]),
        feat_offsets=z[f"{p}lut__feat_offsets"],
        thresholds=[z[f"{p}lut__th_{i}"] for i in range(n_th)],
    )
    s, n_rwd, n_cwd, n_rows, width, n_classes = (
        int(v) for v in z[f"{p}lay__dims"]
    )
    layout = TCAMLayout(
        cells=z[f"{p}lay__cells"], classes=z[f"{p}lay__classes"],
        class_bits=z[f"{p}lay__class_bits"], s=s, n_rwd=n_rwd, n_cwd=n_cwd,
        n_rows=n_rows, width=width, n_classes=n_classes,
    )
    return CompiledDT(tree=tree, table=table, lut=lut, layout=layout)


def _pack_forest(forest) -> dict:
    d: dict = {
        "f__n_banks": np.int64(forest.n_banks),
        "f__n_features": np.int64(forest.n_features),
        "f__n_classes": np.int64(forest.n_classes),
        "f__classes": np.asarray(forest.classes),
        "f__cast_f32": np.int64(int(forest.cast_f32)),
        "f__s": np.int64(forest.s),
    }
    for i, bank in enumerate(forest.banks):
        d.update(_pack_compiled(bank.compiled, f"b{i}__"))
        if bank.proba is not None:
            d[f"b{i}__proba"] = bank.proba
    return d


def _unpack_forest(z, vote: str):
    # lazy import: repro.forest pulls sklearn_io; keep registry import-light
    from ..forest.compiler import CompiledForest, ForestBank

    n = int(z["f__n_banks"])
    banks = []
    for i in range(n):
        banks.append(ForestBank(
            compiled=_unpack_compiled(z, f"b{i}__"),
            proba=z[f"b{i}__proba"] if f"b{i}__proba" in z else None,
        ))
    return CompiledForest(
        banks=banks,
        n_features=int(z["f__n_features"]),
        n_classes=int(z["f__n_classes"]),
        classes=z["f__classes"],
        vote=vote,
        cast_f32=bool(int(z["f__cast_f32"])),
        s=int(z["f__s"]),
    )


def content_hash(compiled) -> str:
    """sha256 over every array of the compiled artifact, in sorted key
    order with dtype+shape framing — identical compiles hash identically
    regardless of process or platform."""
    packed = (_pack_forest(compiled) if hasattr(compiled, "banks")
              else _pack_compiled(compiled))
    h = hashlib.sha256()
    for key in sorted(packed):
        a = np.ascontiguousarray(np.asarray(packed[key]))
        h.update(key.encode())
        h.update(str(a.dtype).encode())
        h.update(np.asarray(a.shape, np.int64).tobytes())
        h.update(a.tobytes())
    return h.hexdigest()


# ---------------------------------------------------------------------------
# the registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ModelVersion:
    """One published model version (the index entry, JSON-shaped)."""

    version_id: str               # "<name>:<hash12>"
    name: str
    kind: str                     # 'tree' | 'forest'
    content_hash: str             # full sha256
    parents: tuple[str, ...]      # parent version ids (lineage)
    created: str                  # ISO timestamp (informational only)
    metadata: dict
    n_features: int
    n_classes: int
    s: int
    lut_shape: tuple[int, int]    # rows, width (summed over banks)
    n_banks: int
    seq: int = 0                  # monotonic publication order

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["parents"] = list(self.parents)
        d["lut_shape"] = list(self.lut_shape)
        return d

    @staticmethod
    def from_json(d: dict) -> "ModelVersion":
        return ModelVersion(
            version_id=d["version_id"], name=d["name"], kind=d["kind"],
            content_hash=d["content_hash"], parents=tuple(d["parents"]),
            created=d["created"], metadata=d.get("metadata", {}),
            n_features=int(d["n_features"]), n_classes=int(d["n_classes"]),
            s=int(d["s"]), lut_shape=tuple(d["lut_shape"]),
            n_banks=int(d["n_banks"]), seq=int(d.get("seq", 0)),
        )


class ModelRegistry:
    """File-backed versioned registry of compiled models.

    >>> reg = ModelRegistry("artifacts/registry")
    >>> v1 = reg.publish(compiled_v1, "traffic")
    >>> v2 = reg.publish(compiled_v2, "traffic", parents=[v1.version_id])
    >>> live = reg.load(reg.latest("traffic").version_id)
    """

    def __init__(self, root: str) -> None:
        self.root = str(root)
        os.makedirs(self.root, exist_ok=True)
        self._index: dict[str, ModelVersion] = {}
        self._load_index()

    # -- index persistence --------------------------------------------------
    def _index_path(self) -> str:
        return os.path.join(self.root, _INDEX)

    def _load_index(self) -> None:
        path = self._index_path()
        if not os.path.exists(path):
            return
        with open(path) as f:
            raw = json.load(f)
        self._index = {
            vid: ModelVersion.from_json(meta)
            for vid, meta in raw.get("versions", {}).items()
        }

    def _save_index(self) -> None:
        tmp = self._index_path() + ".tmp"
        with open(tmp, "w") as f:
            json.dump(
                {"versions": {vid: v.to_json()
                              for vid, v in sorted(self._index.items())}},
                f, indent=2,
            )
        os.replace(tmp, self._index_path())

    def _blob_path(self, version_id: str) -> str:
        return os.path.join(self.root, version_id.replace(":", "__") + ".npz")

    # -- publish / load -----------------------------------------------------
    def publish(
        self,
        compiled,
        name: str,
        *,
        parents: Sequence[str] = (),
        metadata: Optional[dict] = None,
    ) -> ModelVersion:
        """Store a compiled model; returns its (possibly pre-existing)
        version.  Idempotent: identical content under the same name maps to
        the same version id and is not re-written."""
        if ":" in name or "/" in name:
            raise ValueError(f"model name {name!r} may not contain ':' or '/'")
        for p in parents:
            if p not in self._index:
                raise KeyError(f"parent version {p!r} not in registry")
        is_forest = hasattr(compiled, "banks")
        chash = content_hash(compiled)
        vid = f"{name}:{chash[:12]}"
        if vid in self._index:
            return self._index[vid]
        packed = _pack_forest(compiled) if is_forest \
            else _pack_compiled(compiled)
        np.savez_compressed(self._blob_path(vid), **packed)
        if is_forest:
            kind, n_banks = "forest", compiled.n_banks
            n_features, n_classes, s = (compiled.n_features,
                                        compiled.n_classes, compiled.s)
            lut_shape = (sum(b.lut.n_rows for b in compiled.banks),
                         max(b.lut.width for b in compiled.banks))
            metadata = dict(metadata or {})
            metadata.setdefault("vote", compiled.vote)
        else:
            kind, n_banks = "tree", 1
            n_features = compiled.tree.n_features
            n_classes = compiled.tree.n_classes
            s = compiled.layout.s
            lut_shape = compiled.lut_shape
            metadata = dict(metadata or {})
        version = ModelVersion(
            version_id=vid, name=name, kind=kind, content_hash=chash,
            parents=tuple(parents),
            created=time.strftime("%Y-%m-%dT%H:%M:%S"),
            metadata=metadata, n_features=int(n_features),
            n_classes=int(n_classes), s=int(s),
            lut_shape=(int(lut_shape[0]), int(lut_shape[1])),
            n_banks=int(n_banks),
            seq=1 + max((v.seq for v in self._index.values()), default=0),
        )
        self._index[vid] = version
        self._save_index()
        return version

    def load(self, version_id: str):
        """Reconstruct the compiled model of a version (round-trip exact)."""
        v = self.get(version_id)
        with np.load(self._blob_path(v.version_id)) as z:
            if v.kind == "forest":
                return _unpack_forest(z, v.metadata.get("vote", "hard"))
            return _unpack_compiled(z)

    # -- queries ------------------------------------------------------------
    def get(self, version_id: str) -> ModelVersion:
        if version_id not in self._index:
            raise KeyError(f"unknown version {version_id!r}")
        return self._index[version_id]

    def versions(self, name: Optional[str] = None) -> list[ModelVersion]:
        """All versions (of one model name, if given), oldest-published
        first.  Ordered by publication sequence, not index-file key order —
        the persisted index is key-sorted for diff stability."""
        out = [v for v in self._index.values()
               if name is None or v.name == name]
        out.sort(key=lambda v: v.seq)
        return out

    def latest(self, name: str) -> ModelVersion:
        vs = self.versions(name)
        if not vs:
            raise KeyError(f"no versions published under {name!r}")
        return vs[-1]

    def lineage(self, version_id: str) -> list[ModelVersion]:
        """Ancestry walk: the version, its first parent, that parent's
        first parent, ... oldest last."""
        out = []
        seen = set()
        vid: Optional[str] = version_id
        while vid is not None and vid not in seen:
            seen.add(vid)
            v = self.get(vid)
            out.append(v)
            vid = v.parents[0] if v.parents else None
        return out

    def __len__(self) -> int:
        return len(self._index)

    def __contains__(self, version_id: str) -> bool:
        return version_id in self._index
