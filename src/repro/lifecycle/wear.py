"""Per-cell endurance tracking and the wear-leveling row remapper.

ReRAM elements survive a finite number of program pulses
(``HardwareParams.endurance_writes``).  ``WearTracker`` accumulates the pulse
maps of every executed ``WritePlan`` so a deployment knows, per cell, how
much endurance each redeploy consumed and which cells are approaching
failure.

``wear_level_rows`` is the placement half of the endurance story: TCAM rows
of a reduced decision tree are mutually exclusive rules (disjoint tree
paths), so the *physical* row a rule lands on is a free variable.  The
remapper assigns each logical LUT row of a candidate layout to a physical
row chosen to minimize

    write pulses needed (element diff vs. the row's current content)
      + alpha * mean accumulated wear of the physical row,

greedily in LUT-priority order — similar retrained rules land on the rows
that already hold their closest predecessor (fewer writes), and repeated
redeploys spread programming across the array instead of hammering row 0..R.
Physical rows listed in ``forbidden`` (defective rows from a spare-row
``RepairReport`` — compose via ``report.blocked_rows`` — or worn-out rows
from the tracker) never receive live content; any such row whose current
decoder cell would still match queries is disabled in the remapped intent.

The remapped layout is functionally identical to the candidate (same rules,
same classes — verified by the lifecycle tests); only physical row indices
and therefore ``SimResult.survivors`` values change.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional

import numpy as np

from ..core.energy import DEFAULT_HW, HardwareParams
from ..core.lut import CELL_1, CELL_X
from ..core.synth import TCAMLayout
from .delta import WritePlan, cell_planes

__all__ = ["WearTracker", "RemapResult", "wear_level_rows"]


class WearTracker:
    """Accumulated per-cell program-pulse counts for one physical array.

    ``record`` adds a ``WritePlan``'s pulse maps (cell pulses land on the
    cell grid; class-bit pulses are tracked as a scalar).  The grid grows
    automatically when a plan's aligned shape exceeds the current one —
    modelling an array sized for the largest layout it ever held.
    """

    def __init__(self, shape: tuple[int, int] = (0, 0),
                 *, hw: HardwareParams = DEFAULT_HW) -> None:
        self.hw = hw
        self.counts = np.zeros(shape, dtype=np.int64)
        self.class_pulses = 0
        self.plans_recorded = 0

    def _grow(self, shape: tuple[int, int]) -> None:
        r = max(self.counts.shape[0], shape[0])
        c = max(self.counts.shape[1], shape[1])
        if (r, c) != self.counts.shape:
            grown = np.zeros((r, c), dtype=np.int64)
            grown[: self.counts.shape[0], : self.counts.shape[1]] = self.counts
            self.counts = grown

    def record(self, plan: WritePlan) -> None:
        self._grow(plan.shape)
        self.counts[: plan.shape[0], : plan.shape[1]] += plan.set_map
        self.counts[: plan.shape[0], : plan.shape[1]] += plan.reset_map
        self.class_pulses += plan.class_set + plan.class_reset
        self.plans_recorded += 1

    # -- endurance accounting ----------------------------------------------
    @property
    def total_pulses(self) -> int:
        return int(self.counts.sum()) + self.class_pulses

    @property
    def max_cell_pulses(self) -> int:
        return int(self.counts.max()) if self.counts.size else 0

    def row_wear(self) -> np.ndarray:
        """(rows,) mean pulses per cell of each physical row."""
        if self.counts.size == 0:
            return np.zeros(0, np.float64)
        return self.counts.mean(axis=1)

    def headroom(self) -> float:
        """Remaining endurance fraction of the most-worn cell (1.0 = fresh,
        <= 0.0 = some cell exceeded its rated endurance)."""
        return 1.0 - self.max_cell_pulses / self.hw.endurance_writes

    def worn_out(self) -> np.ndarray:
        """Boolean grid of cells at/past their rated endurance."""
        return self.counts >= self.hw.endurance_writes

    def worn_rows(self) -> np.ndarray:
        """Physical rows containing at least one worn-out cell — candidates
        for ``wear_level_rows(..., forbidden=...)``."""
        if self.counts.size == 0:
            return np.zeros(0, np.int64)
        return np.flatnonzero(self.worn_out().any(axis=1))

    def snapshot(self) -> dict:
        return {
            "plans_recorded": self.plans_recorded,
            "total_pulses": self.total_pulses,
            "max_cell_pulses": self.max_cell_pulses,
            "mean_cell_pulses": (
                float(self.counts.mean()) if self.counts.size else 0.0
            ),
            "headroom": self.headroom(),
            "worn_cells": int(self.worn_out().sum()),
            "endurance_writes": self.hw.endurance_writes,
        }


def _pulse_cost_matrix(new_rows: np.ndarray,
                       phys_rows: np.ndarray) -> np.ndarray:
    """(L, P) pulses needed to program logical row i onto physical row p:
    element diffs counted via the two LRS bitplanes (two matmuls each)."""
    costs = np.zeros((new_rows.shape[0], phys_rows.shape[0]), np.int64)
    for plane_n, plane_p in zip(cell_planes(new_rows), cell_planes(phys_rows)):
        a = plane_n.astype(np.int64)
        b = plane_p.astype(np.int64)
        # differing elements = a XOR b summed over columns, as matmuls
        costs += a @ (1 - b).T + (1 - a) @ b.T
    return costs


@dataclasses.dataclass
class RemapResult:
    layout: TCAMLayout            # candidate layout with rows re-placed
    row_map: np.ndarray           # (n_rows,) logical LUT row -> physical row
    forbidden: np.ndarray         # (f,) physical rows excluded from placement

    def summary(self) -> dict:
        ident = np.arange(self.row_map.shape[0])
        return {
            "rows_mapped": int(self.row_map.shape[0]),
            "rows_moved": int((self.row_map != ident).sum()),
            "forbidden_rows": int(self.forbidden.shape[0]),
        }


def wear_level_rows(
    candidate: TCAMLayout,
    current_cells: np.ndarray,
    wear: Optional[WearTracker] = None,
    *,
    forbidden: Iterable[int] = (),
    alpha: float = 1.0,
) -> RemapResult:
    """Re-place the candidate layout's logical rows onto physical rows.

    candidate: the compiled layout about to be delta-programmed.
    current_cells: the physical array's current contents (the live intent),
        CELL_X-padded/cropped to the candidate grid automatically.
    wear: accumulated endurance state (None = fresh array, pure
        write-minimisation).
    forbidden: physical rows that must not host live content (defective rows
        from ``RepairReport.blocked_rows``, worn rows from
        ``WearTracker.worn_rows``).
    alpha: wear-avoidance weight — pulses a row's mean historical wear is
        worth during placement (0 = ignore wear entirely).

    Returns a ``RemapResult`` whose ``layout`` matches the candidate
    functionally; physical rows left without a logical row are given a dead
    intent (decoder CELL_1, body CELL_X) so stale rules cannot ghost-match.
    """
    cand_cells = np.asarray(candidate.cells)
    n_phys, width = cand_cells.shape
    n_log = candidate.n_rows
    cur = np.full((n_phys, width), CELL_X, dtype=np.int8)
    src = np.asarray(current_cells)
    r = min(src.shape[0], n_phys)
    c = min(src.shape[1], width)
    cur[:r, :c] = src[:r, :c]

    forbidden = np.unique(np.asarray(list(forbidden), dtype=np.int64)) \
        if not isinstance(forbidden, np.ndarray) else np.unique(forbidden)
    if forbidden.size and (forbidden.min() < 0 or forbidden.max() >= n_phys):
        raise ValueError("forbidden row index out of range")
    allowed = np.setdiff1d(np.arange(n_phys), forbidden)
    if allowed.size < n_log:
        raise ValueError(
            f"cannot place {n_log} logical rows on {allowed.size} allowed "
            f"physical rows ({forbidden.size} forbidden of {n_phys})"
        )

    cost = _pulse_cost_matrix(
        cand_cells[:n_log], cur[allowed]
    ).astype(np.float64)
    if wear is not None and alpha > 0.0:
        rw = np.zeros(n_phys, np.float64)
        hist = wear.row_wear()
        k = min(hist.shape[0], n_phys)
        rw[:k] = hist[:k]
        cost = cost + alpha * rw[allowed][None, :]

    # greedy in LUT-priority order: each logical row takes the cheapest
    # still-open physical slot
    taken = np.zeros(allowed.size, dtype=bool)
    row_map = np.empty(n_log, dtype=np.int64)
    for i in range(n_log):
        open_cost = np.where(taken, np.inf, cost[i])
        pick = int(np.argmin(open_cost))
        taken[pick] = True
        row_map[i] = allowed[pick]

    # dead intent everywhere first (decoder '1' forces mismatch), then place
    # logical row i at physical row_map[i]; its class rides along.  Unplaced
    # rows keep the candidate's rogue-row classes — they are dead anyway.
    cells = np.full((n_phys, width), CELL_X, dtype=np.int8)
    cells[:, 0] = CELL_1
    cells[row_map] = cand_cells[:n_log]
    classes = np.array(candidate.classes, copy=True)
    class_bits = np.array(candidate.class_bits, copy=True)
    classes[row_map] = candidate.classes[:n_log]
    class_bits[row_map] = candidate.class_bits[:n_log]

    layout = dataclasses.replace(
        candidate, cells=cells, classes=classes, class_bits=class_bits
    )
    return RemapResult(layout=layout, row_map=row_map, forbidden=forbidden)
