"""LifecycleManager: registry -> delta plan -> shadow -> promote, end to end.

The manager is the orchestration layer tying the lifecycle pieces together
for one serving deployment:

* it resolves model versions through a ``ModelRegistry``;
* every (re)programming pass is planned at write-pulse resolution
  (``plan_full`` for the initial deploy, ``plan_delta`` for updates),
  optionally wear-leveled (``wear_level_rows``), and recorded into one
  ``WearTracker`` — the chip's cumulative endurance ledger;
* staging/promotion/rollback delegate to the server's shadow slot
  (``TCAMServer.stage/promote/rollback``).

The manager never imports ``repro.serve`` — it receives an already
constructed server object (duck-typed: ``live_intent``, ``live_layout``,
``stage``, ``promote``, ``rollback``, ``staged``), so ``repro.lifecycle``
stays numpy-only and eagerly importable.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from ..core.energy import DEFAULT_HW, HardwareParams
from .delta import WritePlan, plan_delta, plan_full
from .registry import ModelRegistry
from .wear import WearTracker, wear_level_rows

__all__ = ["LifecycleManager"]


class LifecycleManager:
    """Drive one server's model lifecycle against a versioned registry.

    >>> mgr = LifecycleManager(registry, server, live_version=v1.version_id)
    >>> plan = mgr.stage(v2.version_id, mirror_fraction=0.5)
    >>> ... serve traffic; the shadow slot mirrors it ...
    >>> report = mgr.promote(max_disagreement=0.05)
    """

    def __init__(
        self,
        registry: ModelRegistry,
        server=None,
        *,
        live_version: Optional[str] = None,
        hw: HardwareParams = DEFAULT_HW,
        wear: Optional[WearTracker] = None,
    ) -> None:
        self.registry = registry
        self.server = server
        self.hw = hw
        self.wear = wear if wear is not None else WearTracker(hw=hw)
        self.live_version: Optional[str] = None
        self.candidate_version: Optional[str] = None
        self._prev_version: Optional[str] = None
        self.plans: list[WritePlan] = []
        if live_version is not None:
            self.attach(server, live_version)

    # -- binding ------------------------------------------------------------
    def attach(self, server, live_version: str) -> WritePlan:
        """Bind a server already serving ``live_version`` and account the
        initial full programming pass (erased array -> v1) in the wear
        ledger."""
        if server is None:
            raise ValueError("attach requires a server instance")
        v = self.registry.get(live_version)
        if v.kind != "tree":
            raise NotImplementedError(
                "LifecycleManager drives single-model servers; forests are "
                "planned bank-by-bank via plan_forest_delta"
            )
        self.server = server
        self.live_version = live_version
        lay = server.live_layout
        plan = plan_full(
            np.zeros((0, 0), np.int8), server.live_intent,
            new_class_bits=lay.class_bits,
        )
        self.wear.record(plan)
        self.plans.append(plan)
        return plan

    def _require_server(self):
        if self.server is None:
            raise RuntimeError("no server attached; call attach() first")
        return self.server

    # -- the update path ----------------------------------------------------
    def stage(
        self,
        version_id: str,
        *,
        mirror_fraction: float = 0.25,
        wear_level: bool = False,
        forbidden: Sequence[int] = (),
        alpha: float = 1.0,
        full: bool = False,
    ) -> WritePlan:
        """Plan the reprogramming pass live -> ``version_id``, record its
        wear, and stage the candidate into the server's shadow slot.

        ``wear_level=True`` re-places the candidate's rows first
        (``wear_level_rows`` against the live intent and the accumulated
        wear; ``forbidden`` composes with ``RepairReport.blocked_rows``).
        ``full=True`` plans a naive erase-then-program pass instead of the
        delta — the benchmark uses both to report the saving."""
        server = self._require_server()
        candidate = self.registry.load(version_id)
        if hasattr(candidate, "banks"):
            raise NotImplementedError(
                "staging a forest is not supported; see plan_forest_delta"
            )
        old_cells = server.live_intent
        old_bits = server.live_layout.class_bits
        if wear_level:
            remap = wear_level_rows(
                candidate.layout, old_cells, self.wear,
                forbidden=forbidden, alpha=alpha,
            )
            candidate = dataclasses.replace(candidate, layout=remap.layout)
        planner = plan_full if full else plan_delta
        plan = planner(
            old_cells, candidate.layout.cells,
            old_class_bits=old_bits,
            new_class_bits=candidate.layout.class_bits,
        )
        server.stage(candidate, mirror_fraction=mirror_fraction)
        # record only after stage() accepted the candidate — a rejected
        # stage (feature mismatch, slot occupied) programs nothing
        self.wear.record(plan)
        self.plans.append(plan)
        self.candidate_version = version_id
        return plan

    def promote(self, **gates):
        """Evaluate the server's promotion gates; on success the candidate
        version becomes the live version (previous stashed for rollback)."""
        server = self._require_server()
        report = server.promote(**gates)
        if report.promoted:
            self._prev_version = self.live_version
            self.live_version = self.candidate_version
            self.candidate_version = None
        elif not report.staged:
            self.candidate_version = None     # gate rejected: unstaged
        return report

    def rollback(self) -> str:
        """Mirror the server's rollback: unstage the candidate, or revert
        the last promotion (restoring the previous live version)."""
        server = self._require_server()
        action = server.rollback()
        if action == "unstaged":
            self.candidate_version = None
        else:
            self.live_version = self._prev_version
            self._prev_version = None
        return action

    # -- introspection -------------------------------------------------------
    def status(self) -> dict:
        return {
            "live_version": self.live_version,
            "candidate_version": self.candidate_version,
            "staged": (self.server.staged
                       if self.server is not None else False),
            "plans_executed": len(self.plans),
            "last_plan": (self.plans[-1].summary() if self.plans else None),
            "last_plan_figures": (
                self.plans[-1].figures(self.hw) if self.plans else None
            ),
            "wear": self.wear.snapshot(),
        }
