"""Model lifecycle: versioned registry, endurance-aware delta reprogramming,
and zero-downtime promotion.

A TCAM deployment is not compiled once — models retrain, chips wear, and
updates must land without dropping a request.  This package is that half of
the reproduction:

  registry.py — ``ModelRegistry``: content-hashed, lineage-tracked storage of
                compiled models (``.npz`` blobs + JSON index, round-trip
                exact)
  delta.py    — ``plan_delta`` / ``plan_full`` / ``plan_forest_delta``: cell-
                wise layout diffs at write-pulse (SET/RESET per resistive
                element) resolution
  wear.py     — ``WearTracker`` (per-cell endurance ledger) and
                ``wear_level_rows`` (row placement that minimises pulses and
                spreads wear; composes with ``RepairReport.blocked_rows``)
  manager.py  — ``LifecycleManager``: registry -> plan -> shadow -> promote
                against a ``TCAMServer`` (received, never imported — this
                package stays numpy-only)

The serving side (shadow slot, promotion gates, atomic swap) lives on
``repro.serve.TCAMServer``: ``stage()`` / ``promote()`` / ``rollback()``.
"""
from .delta import (
    WritePlan,
    cell_planes,
    plan_delta,
    plan_forest_delta,
    plan_full,
)
from .manager import LifecycleManager
from .registry import ModelRegistry, ModelVersion, content_hash
from .wear import RemapResult, WearTracker, wear_level_rows

__all__ = [
    "WritePlan", "cell_planes", "plan_delta", "plan_full",
    "plan_forest_delta",
    "ModelRegistry", "ModelVersion", "content_hash",
    "WearTracker", "RemapResult", "wear_level_rows",
    "LifecycleManager",
]
