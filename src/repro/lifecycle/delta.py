"""Delta reprogramming planner: cell-wise layout diff -> minimal write plan.

ReCAM cells have finite write endurance (RETENTION: endurance-aware write
reduction is *the* lever for CAM-resident tree ensembles), so redeploying a
retrained tree must not rewrite the whole array.  This module plans the
programming pass at the resolution the hardware actually works at: the two
resistive elements of each 2T2R cell.

A cell state maps to an (R1, R2) LRS/HRS pair (``core.nonideal.CELL_TO_PAIR``);
a state transition costs one SET pulse (HRS -> LRS) or one RESET pulse
(LRS -> HRS) per element that changes:

    CELL_0 -> CELL_1   flips both elements   (1 SET + 1 RESET)
    CELL_0 -> CELL_X   releases R2           (1 RESET)
    CELL_X -> CELL_1   programs R1           (1 SET)
    ...

``plan_delta`` touches only the cells whose state differs between the live
and the candidate layout (plus changed 1T1R class bits); ``plan_full`` models
the naive erase-then-program pass that rewrites every address.  Both return a
``WritePlan`` whose pulse maps feed the endurance tracker
(``lifecycle.wear.WearTracker``) and whose totals feed the write-energy model
(``core.energy.reprogram_figures``).

Layout grids of different physical shape are aligned by padding with CELL_X
(an unprogrammed cell — both elements HRS), modelling one physical array
large enough for both layouts.  ``plan_forest_delta`` diffs a multi-bank
forest bank-by-bank; a bank added by the candidate is programmed from an
erased array, a retired bank is erased.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ..core.energy import DEFAULT_HW, HardwareParams, reprogram_figures
from ..core.lut import CELL_0, CELL_1, CELL_MM, CELL_X

__all__ = ["WritePlan", "cell_planes", "plan_delta", "plan_full",
           "plan_forest_delta"]


def cell_planes(cells: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """(r1_lrs, r2_lrs) boolean element planes of a cell-state grid
    (Table I encoding: CELL_0={HRS,LRS}, CELL_1={LRS,HRS}, CELL_X={HRS,HRS},
    CELL_MM={LRS,LRS})."""
    cells = np.asarray(cells)
    r1 = (cells == CELL_1) | (cells == CELL_MM)
    r2 = (cells == CELL_0) | (cells == CELL_MM)
    return r1, r2


def _pad_grid(cells: np.ndarray, shape: tuple[int, int]) -> np.ndarray:
    """Pad a cell grid with CELL_X (erased) up to ``shape``."""
    cells = np.asarray(cells)
    if cells.shape == shape:
        return cells
    out = np.full(shape, CELL_X, dtype=np.int8)
    out[: cells.shape[0], : cells.shape[1]] = cells
    return out


def _pad_bits(bits: Optional[np.ndarray],
              shape: tuple[int, int]) -> np.ndarray:
    """Pad a class-bit grid with 0 (erased 1T1R) up to ``shape``."""
    out = np.zeros(shape, dtype=np.uint8)
    if bits is not None:
        b = np.asarray(bits)
        out[: b.shape[0], : b.shape[1]] = b
    return out


@dataclasses.dataclass
class WritePlan:
    """One programming pass over a TCAM bank, at write-pulse resolution.

    set_map / reset_map: (rows, cols) int16 — per-cell SET / RESET pulse
    counts over the cell's two elements (0..2 each).  ``rows``/``cols`` index
    the cells receiving at least one pulse; ``old``/``new`` are their cell
    states before/after.  Class-bit (1T1R) writes are tracked as separate
    pulse totals (``class_set``/``class_reset``) plus a per-row map.
    """

    kind: str                     # 'delta' | 'full'
    shape: tuple[int, int]        # aligned cell-grid shape
    rows: np.ndarray              # (k,) int64 cells with >=1 pulse
    cols: np.ndarray              # (k,) int64
    old: np.ndarray               # (k,) int8 cell state before
    new: np.ndarray               # (k,) int8 cell state after
    set_map: np.ndarray           # (rows, cols) int16 SET pulses per cell
    reset_map: np.ndarray         # (rows, cols) int16 RESET pulses per cell
    n_cells_written: int          # addresses the controller programs
    class_set: int                # 1T1R class-bit SET pulses
    class_reset: int              # 1T1R class-bit RESET pulses
    class_rows: np.ndarray        # (m,) int64 rows with class-bit writes

    @property
    def n_set(self) -> int:
        return int(self.set_map.sum())

    @property
    def n_reset(self) -> int:
        return int(self.reset_map.sum())

    @property
    def n_pulses(self) -> int:
        return self.n_set + self.n_reset + self.class_set + self.class_reset

    @property
    def n_cells_changed(self) -> int:
        """Cells whose state actually differs (== cells pulsed)."""
        return int(self.rows.shape[0])

    @property
    def rows_touched(self) -> int:
        return int(np.union1d(self.rows, self.class_rows).shape[0])

    def apply(self, cells: np.ndarray) -> np.ndarray:
        """Apply the plan to a cell grid (after CELL_X-padding it to the
        plan's aligned shape); returns the programmed grid — used to verify
        that delta programming reproduces the target layout exactly."""
        out = _pad_grid(cells, self.shape).copy()
        out[self.rows, self.cols] = self.new
        return out

    def figures(self, hw: HardwareParams = DEFAULT_HW) -> dict:
        """Energy / time / endurance figures (``core.energy``)."""
        return reprogram_figures(self, hw)

    def summary(self) -> dict:
        return {
            "kind": self.kind,
            "cells_written": self.n_cells_written,
            "cells_changed": self.n_cells_changed,
            "rows_touched": self.rows_touched,
            "set_pulses": self.n_set,
            "reset_pulses": self.n_reset,
            "class_set_pulses": self.class_set,
            "class_reset_pulses": self.class_reset,
            "total_pulses": self.n_pulses,
        }


def _aligned(old_cells: np.ndarray, new_cells: np.ndarray):
    old_cells = np.asarray(old_cells)
    new_cells = np.asarray(new_cells)
    shape = (max(old_cells.shape[0], new_cells.shape[0]),
             max(old_cells.shape[1], new_cells.shape[1]))
    return _pad_grid(old_cells, shape), _pad_grid(new_cells, shape), shape


def _element_pulses(old: np.ndarray, new: np.ndarray):
    """(set_map, reset_map) int16 per-cell pulse counts old -> new."""
    r1o, r2o = cell_planes(old)
    r1n, r2n = cell_planes(new)
    set_map = ((~r1o & r1n).astype(np.int16)
               + (~r2o & r2n).astype(np.int16))
    reset_map = ((r1o & ~r1n).astype(np.int16)
                 + (r2o & ~r2n).astype(np.int16))
    return set_map, reset_map


def _class_pulses(old_bits, new_bits, shape_rows: int):
    """1T1R class-bit diff: (set, reset, rows-with-writes)."""
    nb = max(
        0 if old_bits is None else np.asarray(old_bits).shape[1],
        0 if new_bits is None else np.asarray(new_bits).shape[1],
    )
    if nb == 0:
        return 0, 0, np.zeros(0, np.int64)
    ob = _pad_bits(old_bits, (shape_rows, nb)).astype(bool)
    xb = _pad_bits(new_bits, (shape_rows, nb)).astype(bool)
    set_b = ~ob & xb
    reset_b = ob & ~xb
    changed = (set_b | reset_b).any(axis=1)
    return int(set_b.sum()), int(reset_b.sum()), np.flatnonzero(changed)


def plan_delta(
    old_cells: np.ndarray,
    new_cells: np.ndarray,
    *,
    old_class_bits: Optional[np.ndarray] = None,
    new_class_bits: Optional[np.ndarray] = None,
) -> WritePlan:
    """Minimal write plan: pulse only the cells (and class bits) whose state
    differs between the live grid and the candidate grid."""
    old_a, new_a, shape = _aligned(old_cells, new_cells)
    changed = old_a != new_a
    rows, cols = np.nonzero(changed)
    set_map, reset_map = _element_pulses(old_a, new_a)
    # unchanged cells receive no pulses by construction (same state => same
    # element pair), so the maps are already delta-minimal
    cs, cr, crows = _class_pulses(old_class_bits, new_class_bits, shape[0])
    return WritePlan(
        kind="delta",
        shape=shape,
        rows=rows.astype(np.int64),
        cols=cols.astype(np.int64),
        old=old_a[rows, cols],
        new=new_a[rows, cols],
        set_map=set_map,
        reset_map=reset_map,
        n_cells_written=int(changed.sum()),
        class_set=cs,
        class_reset=cr,
        class_rows=crows,
    )


def plan_full(
    old_cells: np.ndarray,
    new_cells: np.ndarray,
    *,
    old_class_bits: Optional[np.ndarray] = None,
    new_class_bits: Optional[np.ndarray] = None,
) -> WritePlan:
    """Naive full reprogramming: erase the whole array (RESET every LRS
    element of the live grid back to HRS), then program every cell of the
    candidate grid (SET its LRS elements).  The controller cycles all
    rows x cols addresses — ``n_cells_written`` is the full grid, and every
    previously-programmed class bit is rewritten."""
    old_a, new_a, shape = _aligned(old_cells, new_cells)
    erased = np.full(shape, CELL_X, dtype=np.int8)
    set_e, reset_e = _element_pulses(old_a, erased)      # erase pass
    set_p, reset_p = _element_pulses(erased, new_a)      # program pass
    set_map = set_e + set_p
    reset_map = reset_e + reset_p
    rows, cols = np.nonzero((set_map + reset_map) > 0)
    nb = max(
        0 if old_class_bits is None else np.asarray(old_class_bits).shape[1],
        0 if new_class_bits is None else np.asarray(new_class_bits).shape[1],
    )
    ob = _pad_bits(old_class_bits, (shape[0], max(nb, 1))).astype(bool)
    xb = _pad_bits(new_class_bits, (shape[0], max(nb, 1))).astype(bool)
    cs = int(xb.sum())                    # program every 1-bit from erased
    cr = int(ob.sum())                    # erase every previously-set bit
    crows = np.flatnonzero(ob.any(axis=1) | xb.any(axis=1)) if nb else \
        np.zeros(0, np.int64)
    return WritePlan(
        kind="full",
        shape=shape,
        rows=rows.astype(np.int64),
        cols=cols.astype(np.int64),
        old=old_a[rows, cols],
        new=new_a[rows, cols],
        set_map=set_map,
        reset_map=reset_map,
        n_cells_written=shape[0] * shape[1],
        class_set=cs,
        class_reset=cr,
        class_rows=crows,
    )


def plan_forest_delta(old_forest, new_forest, *, full: bool = False) -> list:
    """Per-bank write plans migrating one compiled forest to another.

    Banks pair up by index (bank i of the live forest is reprogrammed into
    bank i of the candidate).  A candidate bank beyond the live bank count is
    programmed from an erased array; a live bank beyond the candidate count
    is erased (all its programmed elements RESET).  ``full=True`` emits naive
    full-reprogram plans instead, for comparison.
    """
    old_banks = list(old_forest.banks)
    new_banks = list(new_forest.banks)
    planner = plan_full if full else plan_delta
    plans = []
    for i in range(max(len(old_banks), len(new_banks))):
        ob = old_banks[i] if i < len(old_banks) else None
        cb = new_banks[i] if i < len(new_banks) else None
        oc = ob.layout.cells if ob is not None else np.zeros((0, 0), np.int8)
        ocb = ob.layout.class_bits if ob is not None else None
        if cb is not None:
            nc, ncb = cb.layout.cells, cb.layout.class_bits
        else:
            # retired bank: erase back to all-CELL_X, clear class bits
            nc = np.full_like(oc, CELL_X)
            ncb = None
        plans.append(planner(
            oc, nc, old_class_bits=ocb, new_class_bits=ncb,
        ))
    return plans
