"""Fault-tolerant training loop.

Production posture (DESIGN.md §5): at 1000+ nodes, *something* fails every
few hours.  The loop provides:

  * **checkpoint/restart** — periodic atomic checkpoints; on step failure the
    loop restores the latest valid checkpoint and replays (the data pipeline
    is a pure function of (seed, step), so replay is exact);
  * **bounded retries** — ``max_retries`` consecutive failures abort with the
    last exception (a crash-looping job must page a human);
  * **straggler mitigation** — per-step wall times feed a rolling median; a
    step slower than ``straggler_factor``x the median is logged and counted.
    On real pods the mitigation hook triggers re-compilation onto a spare
    slice (elastic re-mesh via ``checkpoint.restore_resharded``); here the
    hook is observable + testable;
  * **preemption handling** — SIGTERM sets a flag; the loop checkpoints and
    exits cleanly at the next step boundary.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable, Optional

import numpy as np

from ..checkpoint import CheckpointManager

__all__ = ["StragglerMonitor", "FaultTolerantLoop"]


@dataclasses.dataclass
class StragglerMonitor:
    factor: float = 2.5
    window: int = 32
    times: list = dataclasses.field(default_factory=list)
    stragglers: int = 0

    def observe(self, dt: float) -> bool:
        """Record a step time; True if it was a straggler step."""
        is_straggler = False
        if len(self.times) >= 8:
            med = float(np.median(self.times[-self.window:]))
            is_straggler = dt > self.factor * med
        self.times.append(dt)
        if is_straggler:
            self.stragglers += 1
        return is_straggler


class FaultTolerantLoop:
    def __init__(
        self,
        step_fn: Callable,                  # (state, batch) -> (state, metrics)
        batch_fn: Callable,                 # step -> batch
        ckpt: CheckpointManager,
        *,
        ckpt_every: int = 100,
        max_retries: int = 3,
        straggler: Optional[StragglerMonitor] = None,
        on_straggler: Optional[Callable] = None,
        install_sigterm: bool = False,
    ):
        self.step_fn = step_fn
        self.batch_fn = batch_fn
        self.ckpt = ckpt
        self.ckpt_every = ckpt_every
        self.max_retries = max_retries
        self.straggler = straggler or StragglerMonitor()
        self.on_straggler = on_straggler
        self.preempted = False
        self.retries = 0
        if install_sigterm:
            signal.signal(signal.SIGTERM, self._handle_sigterm)

    def _handle_sigterm(self, signum, frame):
        self.preempted = True

    def run(self, state, start_step: int, n_steps: int,
            *, log_every: int = 10, log=print):
        step = start_step
        history = []
        while step < start_step + n_steps:
            if self.preempted:
                self.ckpt.save(step, state)
                log(f"[preempt] checkpointed at step {step}, exiting")
                break
            try:
                t0 = time.perf_counter()
                batch = self.batch_fn(step)
                state, metrics = self.step_fn(state, batch)
                loss = metrics.get("loss")
                if loss is not None:
                    lv = float(loss)
                    if not np.isfinite(lv):
                        raise FloatingPointError(
                            f"non-finite loss {lv} at step {step}")
                dt = time.perf_counter() - t0
                if self.straggler.observe(dt) and self.on_straggler:
                    self.on_straggler(step, dt)
                history.append(
                    {k: float(v) for k, v in metrics.items()} | {"step": step})
                if log_every and step % log_every == 0:
                    log(f"step {step}: " + " ".join(
                        f"{k}={float(v):.4g}" for k, v in metrics.items()))
                self.retries = 0
                step += 1
                if step % self.ckpt_every == 0:
                    self.ckpt.save(step, state)
            except (FloatingPointError, RuntimeError) as e:  # node failure
                self.retries += 1
                log(f"[fault] step {step} failed ({e}); "
                    f"retry {self.retries}/{self.max_retries}")
                if self.retries > self.max_retries:
                    raise
                restored = self.ckpt.restore(state)
                if restored is not None:
                    state, step = restored
                    log(f"[fault] restored checkpoint at step {step}")
        return state, step, history
