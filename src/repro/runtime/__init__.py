"""Fault-tolerant training runtime: retries, straggler detection,
preemption handling, elastic resume."""
from .fault import FaultTolerantLoop, StragglerMonitor

__all__ = ["FaultTolerantLoop", "StragglerMonitor"]
