"""Temporal degradation: the drift/retention model, sensing-margin analyzer,
scrub-and-refresh scheduler, and the serving-engine maintenance integration
(virtual drift clock, margin-policy scrubs, breaker scrub rung)."""
import math
import threading

import numpy as np
import pytest

from repro.core import DT2CAM, NonIdealSpec
from repro.core.energy import (DEFAULT_HW, mismatch_probability,
                               sensing_margins)
from repro.core.lut import CELL_0, CELL_1, CELL_MM, CELL_X
from repro.core.nonideal import DriftSpec, sample_drift
from repro.degradation import (ScrubPolicy, ScrubScheduler, layout_margins,
                               plan_refresh)
from repro.dt import load_split
from repro.lifecycle.wear import WearTracker
from repro.serve import ServeConfig, TCAMServer

DRIFT = DriftSpec(nu=0.05, nu_sigma=0.02, retention_tau_s=2e6)


@pytest.fixture(scope="module")
def iris_model():
    Xtr, ytr, Xte, yte = load_split("iris")
    return DT2CAM(s=16, max_depth=5).fit(Xtr, ytr), Xte, yte


def _grid():
    """A small grid exercising all four cell states."""
    return np.array([[CELL_0, CELL_1, CELL_X],
                     [CELL_1, CELL_MM, CELL_0],
                     [CELL_X, CELL_0, CELL_1]], np.int8)


# --------------------------------------------------------------------------
# drift model
# --------------------------------------------------------------------------
def test_drift_spec_validation_and_ideality():
    assert DriftSpec().is_ideal
    assert not DRIFT.is_ideal
    assert DriftSpec(read_disturb_s=1.0).is_ideal   # no law to accumulate
    for bad in (dict(nu=-0.1), dict(nu_sigma=-1.0), dict(t0=0.0),
                dict(retention_tau_s=0.0), dict(read_disturb_s=-1.0),
                dict(hrs_drift_scale=-0.5)):
        with pytest.raises(ValueError):
            DriftSpec(**bad)
    assert not NonIdealSpec().has_drift
    assert not NonIdealSpec(drift=DriftSpec()).has_drift   # ideal law
    assert NonIdealSpec(drift=DRIFT).has_drift
    assert not NonIdealSpec(drift=DRIFT).is_ideal
    with pytest.raises(TypeError):
        NonIdealSpec(drift=0.1)


def test_drift_zero_stress_is_identity():
    cells = _grid()
    m = sample_drift(cells.shape, DRIFT, np.random.default_rng(0))
    f1, f2 = m.growth(0.0, 0)
    assert np.allclose(f1, 1.0) and np.allclose(f2, 1.0)
    assert (m.readout(cells, 0.0, 0) == cells).all()


def test_drift_growth_monotone_and_retention_flips_to_dont_care():
    cells = _grid()
    spec = DriftSpec(nu=0.0, retention_tau_s=2e6)      # pure retention decay
    m = sample_drift(cells.shape, spec)
    f_a, _ = m.growth(1e5, 0)
    f_b, _ = m.growth(1e6, 0)
    assert (f_b > f_a).all() and (f_a > 1.0).all()
    # past the LRS flip threshold (but short of the attenuated HRS flip) a
    # determinate cell's LRS element reads HRS -> the cell reads as CELL_X,
    # i.e. a silent missed-match, which is exactly what scrubbing prevents
    t = 2e6 * math.log(2 * m.flip_threshold())
    out = m.readout(cells, t, 0)
    det = np.isin(cells, (CELL_0, CELL_1))
    assert (out[det] == CELL_X).all()
    assert (out[cells == CELL_X] == CELL_X).all()


def test_drift_read_disturb_adds_stress():
    spec = DriftSpec(nu=0.1, read_disturb_s=0.5)
    m = sample_drift((2, 3), spec)
    assert np.allclose(m.stress_time(0.0, 100), 50.0)
    assert np.allclose(m.stress_time(10.0, [100, 0]),
                       np.array([[60.0], [10.0]]))
    f_idle, _ = m.growth(10.0, 0)
    f_read, _ = m.growth(10.0, 100)
    assert (f_read > f_idle).all()


def test_sample_drift_seeded_and_rng_required():
    a = sample_drift((4, 4), DRIFT, np.random.default_rng(7))
    b = sample_drift((4, 4), DRIFT, np.random.default_rng(7))
    assert (a.nu_r1 == b.nu_r1).all() and (a.nu_r2 == b.nu_r2).all()
    assert (a.nu_r1 >= 0).all()
    with pytest.raises(TypeError, match="rng"):
        sample_drift((4, 4), DRIFT)                    # nu_sigma > 0
    c = sample_drift((4, 4), DriftSpec(nu=0.05))       # deterministic law
    assert (c.nu_r1 == 0.05).all()


# --------------------------------------------------------------------------
# sensing margins
# --------------------------------------------------------------------------
def test_sensing_margins_ideal_grid_positive():
    hw = DEFAULT_HW
    rows, cols, s = 4, 8, 4
    r_match = np.full((rows, cols), hw.r_cell_match)
    r_mismatch = np.full((rows, cols), hw.r_cell_mismatch)
    sm = sensing_margins(r_match, r_mismatch, s=s, used=cols, hw=hw)
    assert sm.margin_match.shape == (rows,)
    assert (sm.margin > 0).all()
    # trimmed references sit midway between full-match and 1-mismatch
    assert np.allclose(sm.margin_match, sm.margin_mismatch)
    assert sm.summary()["rows_negative"] == 0
    # HRS drifting down leaks the matching line -> match margin erodes;
    # LRS drifting up weakens the mismatch discharge -> mismatch margin erodes
    leaky = sensing_margins(r_match / 3.0, r_mismatch, s=s, used=cols, hw=hw)
    assert (leaky.margin_match < sm.margin_match).all()
    weak = sensing_margins(r_match, r_mismatch * 3.0, s=s, used=cols, hw=hw)
    assert (weak.margin_mismatch < sm.margin_mismatch).all()
    with pytest.raises(ValueError):
        sensing_margins(r_match, r_mismatch[:, :4], s=s, used=cols)


def test_mismatch_probability_limits():
    m = np.array([-0.2, 0.0, 0.2])
    assert (mismatch_probability(m, 0.0) == [1.0, 0.5, 0.0]).all()
    p = mismatch_probability(m, 0.05)
    assert p[0] > 0.99 and p[2] < 0.01
    assert p[1] == pytest.approx(0.5)
    assert np.allclose(p + mismatch_probability(-m, 0.05), 1.0)
    with pytest.raises(ValueError):
        mismatch_probability(m, -1.0)


# --------------------------------------------------------------------------
# refresh plans + scheduler
# --------------------------------------------------------------------------
def test_plan_refresh_pulse_accounting_and_identity():
    cells = _grid()
    plan = plan_refresh(cells, [0, 2], used=3)
    assert plan.kind == "refresh"
    # one reinforcing pulse per resistive element: 2 per cell, 3 cells/row
    assert plan.n_set + plan.n_reset == 2 * 2 * 3
    assert plan.n_pulses == plan.n_set + plan.n_reset
    assert (plan.old == plan.new).all()                # refresh changes nothing
    assert (plan.apply(cells) == plan.apply(plan.apply(cells))).all()
    figs = plan.figures(DEFAULT_HW)
    assert figs["energy_j"] > 0 and figs["pulses"] == plan.n_pulses
    assert plan.rows_touched == 2
    assert sorted(np.unique(plan.rows).tolist()) == [0, 2]
    with pytest.raises(ValueError):
        plan_refresh(cells, [5])


def test_scrub_scheduler_margin_policy_selection():
    wear = WearTracker((6, 3))
    sch = ScrubScheduler(
        6, policy=ScrubPolicy(kind="margin", margin_v=0.15, max_rows=2),
        wear=wear,
    )
    margins = np.array([0.5, 0.10, 0.05, 0.2, -0.1, 0.12])
    assert sch.due(margins, blocked=()).tolist() == [4, 2]  # worst-first, cap
    assert sch.due(margins, blocked=[2]).tolist() == [4, 1]
    cells = np.full((6, 3), CELL_1, np.int8)
    sch.advance(100.0)
    plan, report = sch.scrub(cells, margins, used=3, blocked=[2])
    assert report.rows_due == 4                       # policy wanted 4 rows
    assert report.rows_refreshed.tolist() == [4, 1]   # blocked + capped
    assert set(report.rows_skipped.tolist()) == {2, 5}
    assert report.margin_min_v == pytest.approx(-0.1)
    # refreshed rows' drift clocks restart; others keep aging
    ages = sch.ages()
    assert ages[4] == ages[1] == 0.0 and ages[0] == 100.0
    # the shared endurance ledger saw exactly the plan's pulses
    assert wear.total_pulses == plan.n_pulses == report.figures["pulses"]
    snap = sch.snapshot()
    assert snap["scrub_passes"] == 1 and snap["rows_refreshed_total"] == 2
    assert snap["refresh_pulses"] == plan.n_pulses


def test_scrub_scheduler_periodic_policy_and_forced():
    sch = ScrubScheduler(4, policy=ScrubPolicy(kind="periodic", period_s=100))
    sch.advance(100.0)
    sch.note_write([0])
    sch.advance(50.0)
    assert sch.due().tolist() == [1, 2, 3]            # oldest first, 0 fresh
    cells = np.full((4, 2), CELL_0, np.int8)
    _, report = sch.scrub(cells, force_rows=[0, 1], used=2)
    assert report.policy == "forced"
    assert report.rows_refreshed.tolist() == [0, 1]
    sch.note_reads(5)
    sch.note_reads(3, rows=[2])
    assert sch.reads.tolist() == [5, 5, 8, 5]
    assert sch.snapshot()["max_reads"] == 8


def test_scrub_scheduler_validation():
    with pytest.raises(ValueError):
        ScrubPolicy(kind="eager")
    with pytest.raises(ValueError):
        ScrubPolicy(period_s=0.0)
    with pytest.raises(ValueError):
        ScrubPolicy(max_rows=0)
    with pytest.raises(ValueError):
        ScrubScheduler(0)
    sch = ScrubScheduler(3)
    with pytest.raises(ValueError):
        sch.advance(-1.0)
    with pytest.raises(ValueError):
        sch.due()                                     # margin policy, no margins
    with pytest.raises(ValueError):
        sch.due(np.zeros(2))                          # wrong margins shape


def test_layout_margins_monotone_in_drift(iris_model):
    m, _, _ = iris_model
    lay = m.compiled.layout
    drift = sample_drift(lay.cells.shape, DRIFT, np.random.default_rng(0))
    mins = [float(layout_margins(lay, drift, t, 0).margin.min())
            for t in (0.0, 1e5, 1e6, 1e7)]
    assert mins == sorted(mins, reverse=True)         # margins only erode
    assert mins[0] > 0 > mins[-1]                     # fresh ok, aged broken


# --------------------------------------------------------------------------
# serving integration
# --------------------------------------------------------------------------
def _drift_server(m, **cfg_kw):
    kw = dict(background=False, max_batch=16, engine="ref")
    kw.update(cfg_kw)
    return TCAMServer(m.compiled, nonideal=NonIdealSpec(drift=DRIFT),
                      config=ServeConfig(**kw),
                      rng=np.random.default_rng(0))


def _acc(srv, X, y):
    preds = np.array([r.prediction for r in srv.serve(X)])
    return float((preds == y).mean())


def test_server_drift_collapse_and_scrub_restores(iris_model):
    m, Xte, yte = iris_model
    srv = _drift_server(m)
    assert srv.drift_enabled
    fresh = _acc(srv, Xte, yte)
    srv.advance_time(3e7)                             # deep into retention loss
    aged = _acc(srv, Xte, yte)
    assert aged < fresh - 0.2
    assert srv.margins().summary()["rows_negative"] > 0
    report = srv.scrub_now()
    assert report.n_refreshed > 0
    assert _acc(srv, Xte, yte) == pytest.approx(fresh)
    deg = srv.metrics()["degradation"]
    assert deg["scrub_passes"] == 1 and deg["rows_scrubbed"] > 0
    assert deg["scrub_energy_j"] > 0
    health = srv.health()["degradation"]
    # refresh pulses land in the shared endurance ledger too
    assert health["wear"]["total_pulses"] == deg["scrub_pulses"] > 0
    assert health["margins"]["rows_negative"] == 0    # post-scrub
    srv.close()


def test_server_without_drift_rejects_maintenance(iris_model):
    m, _, _ = iris_model
    srv = TCAMServer(m.compiled, config=ServeConfig(background=False))
    assert not srv.drift_enabled
    assert srv.health()["degradation"] is None
    for call in (lambda: srv.advance_time(1.0), srv.margins, srv.scrub_now):
        with pytest.raises(RuntimeError, match="NonIdealSpec"):
            call()
    srv.close()


def test_server_batch_driven_maintenance(iris_model):
    m, Xte, _ = iris_model
    srv = _drift_server(
        m, scrub_every_batches=1, scrub_policy="periodic",
        scrub_period_s=1.5e6, time_per_batch_s=1e6,
    )
    for _ in range(4):                                # 4 batches = 4e6 virtual s
        srv.serve(Xte[:8])
    deg = srv.metrics()["degradation"]
    assert deg["scrub_passes"] >= 1 and deg["rows_scrubbed"] > 0
    snap = srv.health()["degradation"]
    assert snap["now_s"] == pytest.approx(4e6)
    assert snap["max_age_s"] < 4e6                    # refreshes happened
    srv.close()


def test_breaker_scrub_rung_and_reentry(iris_model):
    """Drifted chip -> canary trip -> scrub rung recovers (REPAIRED, no
    spare-row repair consumed) -> next routine canary re-enters HEALTHY."""
    m, Xte, _ = iris_model
    srv = _drift_server(m, canary_every_batches=1, canary_size=32)
    srv.advance_time(3e7)
    srv.serve(Xte[:8])                                # trips + recovers inline
    h = srv.health()
    assert h["breaker"]["recovery"] == "scrub"
    assert h["repair_attempts"] == 0                  # scrub rung was enough
    assert srv.metrics()["degradation"]["scrub_passes"] >= 1
    srv.serve(Xte[:8])                                # routine canary re-passes
    assert srv.health()["state"] == "healthy"
    srv.close()


def test_scrub_never_drops_inflight_requests(iris_model):
    """Chaos-style: a scrub storm concurrent with a live request stream must
    never drop or double-resolve a future."""
    m, Xte, _ = iris_model
    srv = _drift_server(m, background=True)
    stop = threading.Event()

    def scrubber():
        while not stop.is_set():
            srv.advance_time(5e5)
            srv.scrub_now(force=True)

    th = threading.Thread(target=scrubber, daemon=True)
    th.start()
    try:
        futs = [srv.submit(Xte[i % len(Xte)]) for i in range(64)]
        srv.drain(timeout=60)
    finally:
        stop.set()
        th.join(timeout=30)
    assert all(f.done() and f.exception() is None for f in futs)
    assert srv.metrics()["requests_served"] == 64
    assert srv.metrics()["degradation"]["scrub_passes"] > 0
    srv.close()
