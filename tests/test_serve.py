"""Serving engine: adaptive batching, bucket-bounded jit compiles, metrics,
engine fallback, and the >=1k-request smoke test from the PR acceptance
criteria."""
import threading
import time

import numpy as np
import pytest

from repro.core import DT2CAM, NonIdealSpec
from repro.dt import load_split
from repro.serve import (AdaptiveBatcher, BucketPolicy, CompileCache,
                         ComputeFailed, DeadlineExceeded, LatencyStats,
                         Rejected, ServeConfig, TCAMServer)


@pytest.fixture(scope="module")
def iris_model():
    Xtr, ytr, Xte, yte = load_split("iris")
    return DT2CAM(s=16, max_depth=5).fit(Xtr, ytr), Xte, yte


# --------------------------------------------------------------------------
# pure-logic units
# --------------------------------------------------------------------------
def test_bucket_policy_ladder_and_lookup():
    p = BucketPolicy(max_batch=100, min_bucket=8)
    assert p.buckets == (8, 16, 32, 64, 100)
    assert p.bucket_for(1) == 8
    assert p.bucket_for(8) == 8
    assert p.bucket_for(9) == 16
    assert p.bucket_for(65) == 100
    assert p.bucket_for(100) == 100
    with pytest.raises(ValueError):
        p.bucket_for(101)
    with pytest.raises(ValueError):
        p.bucket_for(0)
    with pytest.raises(ValueError):
        BucketPolicy(max_batch=4, min_bucket=8)


def test_adaptive_batcher_flush_rules():
    b = AdaptiveBatcher(max_batch=4, max_delay_s=1.0)
    assert not b.ready(0.0) and b.deadline() is None
    b.add("a", 0.0)
    assert b.deadline() == 1.0
    assert not b.ready(0.5)          # neither full nor expired
    assert b.ready(1.0)              # oldest hit its deadline
    for x in "bcd":
        b.add(x, 0.1)
    assert b.ready(0.2)              # full
    batch = b.pop_batch()
    assert [p.item for p in batch] == list("abcd")   # FIFO order
    assert len(b) == 0 and not b.ready(2.0)


def test_adaptive_batcher_expiry_awareness():
    b = AdaptiveBatcher(max_batch=8, max_delay_s=1.0, timeout_s=0.1)
    b.add("a", 0.0)
    b.add("b", 0.05)
    assert b.deadline() == pytest.approx(0.1)    # expiry before flush
    assert not b.flush_due(0.2) and b.ready(0.2)  # woken by expiry alone
    b.add("c", 0.15)
    assert [p.item for p in b.pop_expired(0.2)] == ["a", "b"]
    assert [p.item for p in b.pop_expired(0.2)] == []   # "c" still live
    assert len(b) == 1
    assert b.deadline() == pytest.approx(0.25)
    with pytest.raises(ValueError):
        AdaptiveBatcher(max_batch=8, max_delay_s=1.0, timeout_s=-1.0)
    # without a timeout the old flush-only semantics are unchanged
    nb = AdaptiveBatcher(max_batch=8, max_delay_s=1.0)
    nb.add("x", 0.0)
    assert nb.deadline() == 1.0 and nb.pop_expired(100.0) == []


def test_latency_stats_percentiles():
    ls = LatencyStats(capacity=100)
    for v in np.linspace(0.001, 0.1, 100):
        ls.record(float(v))
    assert ls.count == 100
    assert ls.p50 == pytest.approx(0.0505, rel=0.05)
    assert ls.p99 > ls.p50
    assert np.isnan(LatencyStats().p50)


def test_latency_stats_empty_window_is_nan_everywhere():
    ls = LatencyStats()
    assert ls.count == 0
    for v in (ls.p50, ls.p99, ls.mean, ls.percentile(10.0)):
        assert np.isnan(v)
    s = ls.summary_ms()
    assert np.isnan(s["p50_ms"]) and np.isnan(s["p99_ms"])
    assert np.isnan(s["mean_ms"]) and s["count"] == 0.0


def test_latency_stats_single_sample_collapses_percentiles():
    ls = LatencyStats()
    ls.record(0.042)
    assert ls.count == 1
    assert ls.p50 == ls.p99 == ls.mean == pytest.approx(0.042)
    s = ls.summary_ms()
    assert s["p50_ms"] == s["p99_ms"] == pytest.approx(42.0)


def test_latency_stats_identical_samples_p50_equals_p99():
    ls = LatencyStats(capacity=16)
    for _ in range(50):                  # also wraps the bounded ring
        ls.record(0.007)
    assert ls.count == 50
    assert ls.p50 == ls.p99 == pytest.approx(0.007)
    assert ls.percentile(0.0) == ls.percentile(100.0) == pytest.approx(0.007)


def test_compile_cache_lru_bound_and_eviction_counter():
    built = []

    def builder(bucket, engine):
        built.append((bucket, engine))
        return lambda x, b=bucket: (b, x)

    c = CompileCache(builder, "lay0", maxsize=2)
    c.get(8, "mxu")
    c.get(16, "mxu")
    assert c.get(8, "mxu")(0) == (8, 0)          # hit, now most recent
    c.get(32, "mxu")                             # evicts LRU key (16)
    assert len(c) == 2 and c.evictions == 1
    c.get(16, "mxu")                             # rebuild: a fresh miss
    assert built == [(8, "mxu"), (16, "mxu"), (32, "mxu"), (16, "mxu")]
    st = c.stats()
    assert st == {"hits": 1, "misses": 4, "evictions": 2,
                  "size": 2, "maxsize": 2}
    with pytest.raises(ValueError):
        CompileCache(builder, "lay0", maxsize=0)
    # unbounded default: nothing ever evicted
    u = CompileCache(builder, "lay1")
    for b in (8, 16, 32, 64):
        u.get(b, "ref")
    assert len(u) == 4 and u.evictions == 0
    assert u.stats()["maxsize"] is None


def test_server_honors_compile_cache_size(iris_model):
    m, Xte, _ = iris_model
    cfg = ServeConfig(background=False, max_batch=64, min_bucket=8,
                      engine="ref", compile_cache_size=2)
    srv = TCAMServer(m.compiled, config=cfg)
    srv.warmup()                                 # 4 buckets through size-2 LRU
    st = srv.cache.stats()
    assert st["size"] <= 2 and st["evictions"] >= 2
    res = srv.serve(Xte[:5])                     # evicted shapes rebuild fine
    assert len(res) == 5
    srv.close()


def test_fault_hook_old_name_expired(iris_model):
    """The compute_fault_hook -> fault_injection_hook deprecation window is
    over: the old name now raises an actionable AttributeError both ways
    (see README migration notes)."""
    m, _, _ = iris_model
    srv = TCAMServer(m.compiled, config=ServeConfig(background=False))
    with pytest.raises(AttributeError, match="fault_injection_hook"):
        srv.compute_fault_hook = lambda _X: None
    with pytest.raises(AttributeError, match="fault_injection_hook"):
        _ = srv.compute_fault_hook
    srv.fault_injection_hook = None          # the new name still works
    assert srv.fault_injection_hook is None
    srv.close()


# --------------------------------------------------------------------------
# acceptance smoke: >= 1k requests, bounded compiles
# --------------------------------------------------------------------------
def test_smoke_1k_requests_bucket_batching(iris_model):
    m, Xte, yte = iris_model
    n_requests = 1024
    cfg = ServeConfig(max_batch=64, min_bucket=8, background=False)
    srv = TCAMServer(m.compiled, config=cfg)

    rng = np.random.default_rng(0)
    idx = rng.integers(0, len(Xte), size=n_requests)
    futs = []
    sent = 0
    while sent < n_requests:                     # bursty arrivals
        burst = int(rng.integers(1, 2 * cfg.max_batch))
        take = idx[sent : sent + burst]
        futs += srv.submit_many(Xte[take])
        sent += len(take)
        while srv.pump(force=True):
            pass
    srv.drain()

    res = [f.result() for f in futs]
    assert len(res) == n_requests
    stats = srv.metrics()
    assert stats["requests_served"] == n_requests

    # jit cache misses bounded by buckets x engines (acceptance criterion)
    n_buckets = len(srv.policy.buckets)
    assert stats["jit_cache"]["misses"] <= n_buckets * 1
    assert stats["jit_cache"]["hits"] == stats["batches"] - stats["jit_cache"]["misses"]
    # multiple buckets actually exercised by the bursty arrivals
    assert len({r.bucket for r in res}) > 1

    # served decisions identical to the one-shot jax backend
    preds = np.array([r.prediction for r in res])
    ref = m.infer(Xte[idx], backend="jax")
    np.testing.assert_array_equal(preds, ref.predictions)
    np.testing.assert_array_equal(
        np.array([r.energy_j for r in res]), ref.energy_per_dec
    )
    assert stats["total_latency"]["p99_ms"] >= stats["total_latency"]["p50_ms"]
    srv.close()


def test_background_worker_futures_and_deadline_flush(iris_model):
    m, Xte, _ = iris_model
    cfg = ServeConfig(max_batch=512, min_bucket=4, max_delay_s=0.01)
    with TCAMServer(m.compiled, config=cfg) as srv:
        futs = srv.submit_many(Xte[:3])          # far below max_batch
        res = [f.result(timeout=30) for f in futs]   # deadline must flush
        assert all(r.bucket == 4 for r in res)
        stats = srv.metrics()
        assert stats["deadline_flushes"] >= 1
        assert stats["requests_served"] == 3


def test_warmup_precompiles_all_buckets(iris_model):
    m, _, _ = iris_model
    cfg = ServeConfig(max_batch=32, min_bucket=8, background=False)
    srv = TCAMServer(m.compiled, config=cfg)
    assert srv.warmup() == len(srv.policy.buckets)
    assert srv.warmup() == 0                     # second call: all hits
    srv.close()


def test_engine_fallback_when_packed_illegal(iris_model):
    m, Xte, _ = iris_model                       # s=16: packed illegal
    cfg = ServeConfig(engine="packed", background=False, max_batch=8)
    with pytest.warns(RuntimeWarning, match="falling back"):
        srv = TCAMServer(m.compiled, config=cfg)
    assert srv.engine == "mxu"
    res = srv.serve(Xte[:5])
    assert len(res) == 5 and all(r.engine == "mxu" for r in res)
    assert srv.metrics()["engine_fallbacks"] == 1
    srv.close()


def test_packed_engine_served_when_legal():
    Xtr, ytr, Xte, _ = load_split("iris")
    m = DT2CAM(s=32, max_depth=5).fit(Xtr, ytr)
    cfg = ServeConfig(background=False, max_batch=8)
    srv = TCAMServer(m.compiled, config=cfg)
    assert srv.engine == "packed"
    res = srv.serve(Xte[:8])
    ref = m.infer(Xte[:8], backend="jax", engine="packed")
    np.testing.assert_array_equal(
        np.array([r.prediction for r in res]), ref.predictions
    )
    srv.close()


def test_nonideal_serving_runs_and_counts(iris_model):
    m, Xte, yte = iris_model
    cfg = ServeConfig(background=False, max_batch=16)
    srv = TCAMServer(
        m.compiled, config=cfg,
        nonideal=NonIdealSpec(p_sa0=0.01, sa_sigma=0.02, sigma_in=0.02),
        rng=np.random.default_rng(5),
    )
    res = srv.serve(np.tile(Xte, (3, 1)))
    assert len(res) == 3 * len(Xte)
    acc = (np.array([r.prediction for r in res]) == np.tile(yte, 3)).mean()
    assert acc > 0.5                             # degraded but functional
    srv.close()


def test_submit_after_close_rejected(iris_model):
    m, Xte, _ = iris_model
    srv = TCAMServer(m.compiled, config=ServeConfig(background=False))
    srv.close()
    with pytest.raises(RuntimeError, match="closed"):
        srv.submit(Xte[0])


def test_concurrent_submitters_background(iris_model):
    """Several client threads pushing into one server: everything resolves
    and counts line up."""
    m, Xte, _ = iris_model
    cfg = ServeConfig(max_batch=32, min_bucket=8, max_delay_s=0.005)
    results = []
    lock = threading.Lock()
    with TCAMServer(m.compiled, config=cfg) as srv:
        def client(seed):
            rng = np.random.default_rng(seed)
            futs = [srv.submit(Xte[rng.integers(0, len(Xte))])
                    for _ in range(50)]
            out = [f.result(timeout=60) for f in futs]
            with lock:
                results.extend(out)

        threads = [threading.Thread(target=client, args=(i,)) for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        stats = srv.metrics()
    assert len(results) == 200
    assert stats["requests_served"] == 200
    assert stats["jit_cache"]["misses"] <= len(srv.policy.buckets)


# --------------------------------------------------------------------------
# serving protections: worker survival, load shedding, deadlines, retries
# --------------------------------------------------------------------------
def test_worker_survives_batch_compute_failure(iris_model):
    """A batch whose kernel raises fails its futures with ComputeFailed,
    decrements the outstanding count, and leaves the worker alive for the
    next batch."""
    m, Xte, _ = iris_model
    cfg = ServeConfig(max_batch=8, min_bucket=8, max_delay_s=0.001)
    with TCAMServer(m.compiled, config=cfg) as srv:
        boom = [True]

        def hook(_X):
            if boom[0]:
                raise RuntimeError("injected device fault")

        srv.fault_injection_hook = hook
        futs = srv.submit_many(Xte[:8])
        srv.drain(timeout=30)
        for f in futs:
            err = f.exception(timeout=5)
            assert isinstance(err, ComputeFailed)
            assert isinstance(err.__cause__, RuntimeError)
        assert srv._outstanding == 0
        assert srv.metrics()["reliability"]["compute_failures"] == 1

        boom[0] = False                          # worker must still be alive
        res = [f.result(timeout=30) for f in srv.submit_many(Xte[:8])]
        assert len(res) == 8
        assert srv._outstanding == 0


def test_sync_compute_failure_raises_and_recovers(iris_model):
    m, Xte, _ = iris_model
    cfg = ServeConfig(background=False, max_batch=8)
    srv = TCAMServer(m.compiled, config=cfg)

    def hook(_X):
        raise RuntimeError("injected device fault")

    srv.fault_injection_hook = hook
    futs = srv.submit_many(Xte[:4])
    with pytest.raises(ComputeFailed):           # sync mode surfaces the error
        srv.drain()
    assert all(isinstance(f.exception(), ComputeFailed) for f in futs)
    assert srv._outstanding == 0
    srv.fault_injection_hook = None
    assert len(srv.serve(Xte[:4])) == 4
    srv.close()


def test_drain_timeout_raises_with_counters_intact(iris_model):
    m, Xte, _ = iris_model
    cfg = ServeConfig(max_batch=4, min_bucket=4, max_delay_s=0.001)
    gate = threading.Event()
    with TCAMServer(m.compiled, config=cfg) as srv:
        srv.fault_injection_hook = lambda _X: gate.wait(30)
        futs = srv.submit_many(Xte[:4])
        with pytest.raises(TimeoutError):
            srv.drain(timeout=0.1)
        gate.set()                               # un-stick the worker
        srv.drain(timeout=30)
        assert all(f.result(timeout=5) for f in futs)
        assert srv._outstanding == 0
        assert srv.metrics()["requests_served"] == 4


def test_bounded_queue_sheds_with_typed_rejection(iris_model):
    m, Xte, _ = iris_model
    cfg = ServeConfig(max_batch=4, min_bucket=4, max_delay_s=0.001,
                      max_queue=4)
    gate = threading.Event()
    with TCAMServer(m.compiled, config=cfg) as srv:
        srv.fault_injection_hook = lambda _X: gate.wait(30)
        futs = [srv.submit(Xte[i % len(Xte)]) for i in range(30)]
        shed = [f for f in futs if f.done()
                and isinstance(f.exception(), Rejected)]
        assert shed                              # queue cap enforced
        gate.set()
        srv.drain(timeout=30)
        assert all(f.done() for f in futs)       # every future resolved
        assert srv.metrics()["reliability"]["shed"] == len(shed)


def test_request_deadline_expires_in_queue(iris_model):
    m, Xte, _ = iris_model
    cfg = ServeConfig(max_batch=4, min_bucket=4, max_delay_s=0.001,
                      request_timeout_s=0.02)
    gate = threading.Event()
    with TCAMServer(m.compiled, config=cfg) as srv:
        srv.fault_injection_hook = lambda _X: gate.wait(30)
        futs = srv.submit_many(Xte[:12])         # batch 1 stalls; rest queue
        time.sleep(0.1)                          # queued requests expire
        gate.set()
        srv.drain(timeout=30)
        expired = [f for f in futs
                   if isinstance(f.exception(), DeadlineExceeded)]
        assert expired
        assert all(f.done() for f in futs)
        assert (srv.metrics()["reliability"]["deadline_exceeded"]
                == len(expired))


def test_deadline_fires_without_flush_trigger(iris_model):
    # a lone queued request whose timeout is far shorter than max_delay_s
    # must be failed at expiry — the worker wakes on the batcher's expiry
    # deadline, not the (10 s away) flush deadline
    m, Xte, _ = iris_model
    cfg = ServeConfig(max_batch=64, max_delay_s=10.0,
                      request_timeout_s=0.05)
    with TCAMServer(m.compiled, config=cfg) as srv:
        fut = srv.submit(Xte[0])
        t0 = time.time()
        with pytest.raises(DeadlineExceeded):
            fut.result(timeout=5)
        assert time.time() - t0 < 2.0            # nowhere near max_delay_s
        assert srv.metrics()["reliability"]["deadline_exceeded"] == 1


def test_retry_budget_absorbs_transient_faults(iris_model):
    m, Xte, _ = iris_model
    cfg = ServeConfig(background=False, max_batch=8,
                      max_retries=3, retry_backoff_s=0.001)
    srv = TCAMServer(m.compiled, config=cfg)
    fails = [2]

    def flaky(_X):
        if fails[0] > 0:
            fails[0] -= 1
            raise RuntimeError("transient")

    srv.fault_injection_hook = flaky
    res = srv.serve(Xte[:8])
    assert len(res) == 8                         # recovered within budget
    rel = srv.metrics()["reliability"]
    assert rel["retries"] == 2 and rel["compute_failures"] == 0
    srv.close()
