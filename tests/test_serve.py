"""Serving engine: adaptive batching, bucket-bounded jit compiles, metrics,
engine fallback, and the >=1k-request smoke test from the PR acceptance
criteria."""
import threading

import numpy as np
import pytest

from repro.core import DT2CAM, NonIdealSpec
from repro.dt import load_split
from repro.serve import (AdaptiveBatcher, BucketPolicy, LatencyStats,
                         ServeConfig, TCAMServer)


@pytest.fixture(scope="module")
def iris_model():
    Xtr, ytr, Xte, yte = load_split("iris")
    return DT2CAM(s=16, max_depth=5).fit(Xtr, ytr), Xte, yte


# --------------------------------------------------------------------------
# pure-logic units
# --------------------------------------------------------------------------
def test_bucket_policy_ladder_and_lookup():
    p = BucketPolicy(max_batch=100, min_bucket=8)
    assert p.buckets == (8, 16, 32, 64, 100)
    assert p.bucket_for(1) == 8
    assert p.bucket_for(8) == 8
    assert p.bucket_for(9) == 16
    assert p.bucket_for(65) == 100
    assert p.bucket_for(100) == 100
    with pytest.raises(ValueError):
        p.bucket_for(101)
    with pytest.raises(ValueError):
        p.bucket_for(0)
    with pytest.raises(ValueError):
        BucketPolicy(max_batch=4, min_bucket=8)


def test_adaptive_batcher_flush_rules():
    b = AdaptiveBatcher(max_batch=4, max_delay_s=1.0)
    assert not b.ready(0.0) and b.deadline() is None
    b.add("a", 0.0)
    assert b.deadline() == 1.0
    assert not b.ready(0.5)          # neither full nor expired
    assert b.ready(1.0)              # oldest hit its deadline
    for x in "bcd":
        b.add(x, 0.1)
    assert b.ready(0.2)              # full
    batch = b.pop_batch()
    assert [p.item for p in batch] == list("abcd")   # FIFO order
    assert len(b) == 0 and not b.ready(2.0)


def test_latency_stats_percentiles():
    ls = LatencyStats(capacity=100)
    for v in np.linspace(0.001, 0.1, 100):
        ls.record(float(v))
    assert ls.count == 100
    assert ls.p50 == pytest.approx(0.0505, rel=0.05)
    assert ls.p99 > ls.p50
    assert np.isnan(LatencyStats().p50)


# --------------------------------------------------------------------------
# acceptance smoke: >= 1k requests, bounded compiles
# --------------------------------------------------------------------------
def test_smoke_1k_requests_bucket_batching(iris_model):
    m, Xte, yte = iris_model
    n_requests = 1024
    cfg = ServeConfig(max_batch=64, min_bucket=8, background=False)
    srv = TCAMServer(m.compiled, config=cfg)

    rng = np.random.default_rng(0)
    idx = rng.integers(0, len(Xte), size=n_requests)
    futs = []
    sent = 0
    while sent < n_requests:                     # bursty arrivals
        burst = int(rng.integers(1, 2 * cfg.max_batch))
        take = idx[sent : sent + burst]
        futs += srv.submit_many(Xte[take])
        sent += len(take)
        while srv.pump(force=True):
            pass
    srv.drain()

    res = [f.result() for f in futs]
    assert len(res) == n_requests
    stats = srv.metrics()
    assert stats["requests_served"] == n_requests

    # jit cache misses bounded by buckets x engines (acceptance criterion)
    n_buckets = len(srv.policy.buckets)
    assert stats["jit_cache"]["misses"] <= n_buckets * 1
    assert stats["jit_cache"]["hits"] == stats["batches"] - stats["jit_cache"]["misses"]
    # multiple buckets actually exercised by the bursty arrivals
    assert len({r.bucket for r in res}) > 1

    # served decisions identical to the one-shot jax backend
    preds = np.array([r.prediction for r in res])
    ref = m.infer(Xte[idx], backend="jax")
    np.testing.assert_array_equal(preds, ref.predictions)
    np.testing.assert_array_equal(
        np.array([r.energy_j for r in res]), ref.energy_per_dec
    )
    assert stats["total_latency"]["p99_ms"] >= stats["total_latency"]["p50_ms"]
    srv.close()


def test_background_worker_futures_and_deadline_flush(iris_model):
    m, Xte, _ = iris_model
    cfg = ServeConfig(max_batch=512, min_bucket=4, max_delay_s=0.01)
    with TCAMServer(m.compiled, config=cfg) as srv:
        futs = srv.submit_many(Xte[:3])          # far below max_batch
        res = [f.result(timeout=30) for f in futs]   # deadline must flush
        assert all(r.bucket == 4 for r in res)
        stats = srv.metrics()
        assert stats["deadline_flushes"] >= 1
        assert stats["requests_served"] == 3


def test_warmup_precompiles_all_buckets(iris_model):
    m, _, _ = iris_model
    cfg = ServeConfig(max_batch=32, min_bucket=8, background=False)
    srv = TCAMServer(m.compiled, config=cfg)
    assert srv.warmup() == len(srv.policy.buckets)
    assert srv.warmup() == 0                     # second call: all hits
    srv.close()


def test_engine_fallback_when_packed_illegal(iris_model):
    m, Xte, _ = iris_model                       # s=16: packed illegal
    cfg = ServeConfig(engine="packed", background=False, max_batch=8)
    with pytest.warns(RuntimeWarning, match="falling back"):
        srv = TCAMServer(m.compiled, config=cfg)
    assert srv.engine == "mxu"
    res = srv.serve(Xte[:5])
    assert len(res) == 5 and all(r.engine == "mxu" for r in res)
    assert srv.metrics()["engine_fallbacks"] == 1
    srv.close()


def test_packed_engine_served_when_legal():
    Xtr, ytr, Xte, _ = load_split("iris")
    m = DT2CAM(s=32, max_depth=5).fit(Xtr, ytr)
    cfg = ServeConfig(background=False, max_batch=8)
    srv = TCAMServer(m.compiled, config=cfg)
    assert srv.engine == "packed"
    res = srv.serve(Xte[:8])
    ref = m.infer(Xte[:8], backend="jax", engine="packed")
    np.testing.assert_array_equal(
        np.array([r.prediction for r in res]), ref.predictions
    )
    srv.close()


def test_nonideal_serving_runs_and_counts(iris_model):
    m, Xte, yte = iris_model
    cfg = ServeConfig(background=False, max_batch=16)
    srv = TCAMServer(
        m.compiled, config=cfg,
        nonideal=NonIdealSpec(p_sa0=0.01, sa_sigma=0.02, sigma_in=0.02),
        rng=np.random.default_rng(5),
    )
    res = srv.serve(np.tile(Xte, (3, 1)))
    assert len(res) == 3 * len(Xte)
    acc = (np.array([r.prediction for r in res]) == np.tile(yte, 3)).mean()
    assert acc > 0.5                             # degraded but functional
    srv.close()


def test_submit_after_close_rejected(iris_model):
    m, Xte, _ = iris_model
    srv = TCAMServer(m.compiled, config=ServeConfig(background=False))
    srv.close()
    with pytest.raises(RuntimeError, match="closed"):
        srv.submit(Xte[0])


def test_concurrent_submitters_background(iris_model):
    """Several client threads pushing into one server: everything resolves
    and counts line up."""
    m, Xte, _ = iris_model
    cfg = ServeConfig(max_batch=32, min_bucket=8, max_delay_s=0.005)
    results = []
    lock = threading.Lock()
    with TCAMServer(m.compiled, config=cfg) as srv:
        def client(seed):
            rng = np.random.default_rng(seed)
            futs = [srv.submit(Xte[rng.integers(0, len(Xte))])
                    for _ in range(50)]
            out = [f.result(timeout=60) for f in futs]
            with lock:
                results.extend(out)

        threads = [threading.Thread(target=client, args=(i,)) for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        stats = srv.metrics()
    assert len(results) == 200
    assert stats["requests_served"] == 200
    assert stats["jit_cache"]["misses"] <= len(srv.policy.buckets)
