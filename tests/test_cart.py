"""CART decision-tree training (from scratch)."""
import numpy as np
from hypothesis_compat import given, settings, st

from repro.core import predict, train_tree, tree_paths


def test_pure_data_perfect_fit():
    rng = np.random.default_rng(0)
    X = rng.uniform(size=(200, 3))
    y = ((X[:, 0] > 0.5).astype(int) + (X[:, 1] > 0.3)).astype(np.int64)
    tree = train_tree(X, y, max_depth=8)
    assert (predict(tree, X) == y).mean() == 1.0


def test_depth_and_leaf_budget():
    rng = np.random.default_rng(1)
    X = rng.uniform(size=(500, 4))
    y = rng.integers(0, 2, 500)
    t1 = train_tree(X, y, max_depth=3)
    assert t1.depth() <= 3
    t2 = train_tree(X, y, max_depth=20, max_leaves=10)
    assert t2.n_leaves <= 10


def test_paths_partition_input_space():
    """Every input follows exactly one root->leaf path."""
    rng = np.random.default_rng(2)
    X = rng.uniform(size=(300, 3))
    y = (X[:, 0] + X[:, 1] > 1).astype(np.int64)
    tree = train_tree(X, y, max_depth=6)
    paths = tree_paths(tree)
    Xt = rng.uniform(size=(100, 3))
    hits = np.zeros(100, dtype=int)
    preds = np.zeros(100, dtype=int)
    for conds, cls in paths:
        ok = np.ones(100, bool)
        for f, op, th in conds:
            ok &= (Xt[:, f] <= th) if op == "<=" else (Xt[:, f] > th)
        hits += ok
        preds[ok] = cls
    assert (hits == 1).all()
    np.testing.assert_array_equal(preds, predict(tree, Xt))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 9999), n=st.integers(30, 120))
def test_train_accuracy_beats_majority(seed, n):
    rng = np.random.default_rng(seed)
    X = rng.uniform(size=(n, 2))
    y = (X[:, 0] > 0.5).astype(np.int64)
    if len(np.unique(y)) < 2:
        return
    tree = train_tree(X, y, max_depth=4)
    acc = (predict(tree, X) == y).mean()
    maj = max(np.mean(y == 0), np.mean(y == 1))
    assert acc >= maj
