"""Beyond-paper: decision-tree MoE router compiled to TCAM (DESIGN.md §4)."""
import numpy as np

import jax
import jax.numpy as jnp

from repro.core import predict, train_tree
from repro.models.tcam_router import compile_router, route_tcam


def test_router_matches_tree():
    rng = np.random.default_rng(0)
    X = rng.standard_normal((500, 8))
    y = ((X[:, 0] > 0) * 2 + (X[:, 1] > 0.5)).astype(np.int64)   # 4 experts
    tree = train_tree(X, y, max_depth=6)
    bits = compile_router(tree)
    Xt = rng.standard_normal((200, 8))
    want = predict(tree, Xt)
    got = np.asarray(route_tcam(jnp.asarray(Xt, jnp.float32), bits))
    np.testing.assert_array_equal(got, want)


def test_router_in_moe_layer():
    from repro.models.config import ModelConfig
    from repro.models.moe import moe_ffn
    from repro.models.params import init_params
    import dataclasses
    cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=8,
                      n_heads=2, n_kv_heads=2, head_dim=4, d_ff=16,
                      vocab_size=32, pattern=("attn+moe",), n_experts=4,
                      experts_per_token=2, moe_d_ff=16, capacity_factor=8.0,
                      router="tcam_dt")
    p = jax.tree.map(lambda a: a[0],
                     init_params(cfg, jax.random.PRNGKey(0))["blocks"]["attn+moe"])
    rng = np.random.default_rng(1)
    X = rng.standard_normal((400, 8))
    yexp = (X[:, 0] > 0).astype(np.int64) * 3   # experts 0 / 3
    tree = train_tree(X, yexp, max_depth=4)
    bits = compile_router(tree)
    x = jnp.asarray(rng.standard_normal((2, 16, 8)), jnp.float32)
    y = moe_ffn(x, p, cfg, router_bits=bits)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
