"""Shared test fixtures.  NOTE: no XLA_FLAGS here — smoke tests must see the
real (single-device) CPU; only launch/dryrun.py forces 512 devices."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
