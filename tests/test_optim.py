"""Optimizer + compression."""
import numpy as np
from hypothesis_compat import given, settings, st

import jax
import jax.numpy as jnp

from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         cosine_schedule, dequantize_int8, ef_compress,
                         global_norm, quantize_int8)
from repro.optim.compress import ef_init


def test_adamw_converges_on_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=5,
                      total_steps=200)
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw_init(params, cfg)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}        # d/dw of w^2
        params, state, m = adamw_update(grads, state, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_grad_clip_applied():
    cfg = AdamWConfig(lr=1e-3, clip_norm=1.0)
    params = {"w": jnp.zeros(4)}
    state = adamw_init(params, cfg)
    grads = {"w": jnp.full(4, 100.0)}
    _, _, m = adamw_update(grads, state, params, cfg)
    assert float(m["grad_norm"]) > 1.0        # reported pre-clip


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    lrs = [float(cosine_schedule(cfg, jnp.int32(s))) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0
    assert abs(lrs[10] - 1.0) < 0.2
    assert lrs[-1] < 0.2 and lrs[-1] >= 0.1 - 1e-6


def test_bf16_moments_supported():
    cfg = AdamWConfig(mu_dtype="bfloat16")
    params = {"w": jnp.ones(8)}
    state = adamw_init(params, cfg)
    assert state.mu["w"].dtype == jnp.bfloat16
    p2, s2, _ = adamw_update({"w": jnp.ones(8)}, state, params, cfg)
    assert s2.mu["w"].dtype == jnp.bfloat16


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 9999))
def test_quantize_error_bounded(seed):
    """PROPERTY: int8 symmetric quantization error <= scale/2 per element."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(64) * rng.uniform(0.01, 10))
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x)
    assert float(err.max()) <= float(s) * 0.5 + 1e-7


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 9999))
def test_error_feedback_conservation(seed):
    """PROPERTY: g_compressed + r_new == g + r_old (EF conserves mass)."""
    rng = np.random.default_rng(seed)
    g = {"a": jnp.asarray(rng.standard_normal((4, 4)), jnp.float32)}
    r = {"a": jnp.asarray(rng.standard_normal((4, 4)), jnp.float32) * 0.1}
    gq, r2 = ef_compress(g, r)
    np.testing.assert_allclose(np.asarray(gq["a"] + r2["a"]),
                               np.asarray(g["a"] + r["a"]), rtol=1e-5,
                               atol=1e-5)


def test_ef_reduces_bias_over_steps():
    """EF: accumulated compressed sum tracks the true sum."""
    rng = np.random.default_rng(0)
    params = {"w": jnp.zeros(16)}
    resid = ef_init(params)
    true_sum = np.zeros(16)
    comp_sum = np.zeros(16)
    for i in range(50):
        g = {"w": jnp.asarray(rng.standard_normal(16) * 0.01, jnp.float32)}
        true_sum += np.asarray(g["w"])
        gq, resid = ef_compress(g, resid)
        comp_sum += np.asarray(gq["w"])
    residual = np.abs(true_sum - comp_sum).max()
    assert residual <= float(jnp.abs(resid["w"]).max()) + 1e-6
