"""Data pipeline: determinism, shard consistency, resumability."""
import numpy as np

from repro.configs import get_reduced
from repro.data import TokenPipeline


def _pipe(**kw):
    cfg = get_reduced("olmo_1b")
    return TokenPipeline(cfg, global_batch=8, seq_len=32, **kw)


def test_deterministic():
    a = _pipe().batch_at(5)
    b = _pipe().batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])


def test_steps_differ():
    p = _pipe()
    assert not np.array_equal(p.batch_at(1)["tokens"],
                              p.batch_at(2)["tokens"])


def test_labels_are_shifted_tokens():
    b = _pipe().batch_at(0)
    # planted recurrence: labels[t] is the next token of the same stream
    assert b["tokens"].shape == b["labels"].shape


def test_resume_equals_fresh():
    """Pure function of (seed, step): 'resuming' at step k is trivially
    identical to a fresh iterator at k."""
    p = _pipe(seed=3)
    run1 = [p.batch_at(s)["tokens"] for s in range(6)]
    p2 = TokenPipeline(p.cfg, 8, 32, seed=3)     # "restart"
    run2 = [p2.batch_at(s)["tokens"] for s in range(3, 6)]
    for a, b in zip(run1[3:], run2):
        np.testing.assert_array_equal(a, b)


def test_shard_slices_form_global_batch_distribution():
    p = _pipe()
    shards = [p.batch_at(7, shard=i, n_shards=4)["tokens"] for i in range(4)]
    assert all(s.shape == (2, 32) for s in shards)
    # shards must be mutually distinct (different PRNG streams)
    assert not np.array_equal(shards[0], shards[1])


def test_learnable_structure():
    """The planted successor recurrence: labels continue the per-sequence
    stride for most positions (resets/noise excepted)."""
    cfg = get_reduced("olmo_1b")
    p = TokenPipeline(cfg, 4, 256, seed=0, noise=0.0)
    b = p.batch_at(0)
    t, l = b["tokens"].astype(np.int64), b["labels"].astype(np.int64)
    v = cfg.vocab_size
    stride = (l[:, :1] - t[:, :1]) % v
    pred = (t + stride) % v
    frac = (pred == l).mean()
    assert frac > 0.9, frac
