"""Functional simulation == golden DT inference (paper §IV.B) + SP/energy."""
import numpy as np
import pytest

from repro.core import DT2CAM
from repro.core.energy import DEFAULT_HW, f_max, t_cwd
from repro.dt import DATASETS, load_split


@pytest.mark.parametrize("name,s", [("iris", 16), ("iris", 128),
                                    ("cancer", 32), ("haberman", 64),
                                    ("car", 16), ("diabetes", 128)])
def test_sim_equals_golden(name, s):
    """The paper's central validation: ReCAM-simulated accuracy == Python DT
    accuracy under ideal hardware."""
    spec = DATASETS[name]
    Xtr, ytr, Xte, yte = load_split(name)
    m = DT2CAM(s=s, max_depth=spec.max_depth).fit(Xtr, ytr)
    res = m.infer(Xte)
    assert res.accuracy(yte) == m.golden_accuracy(Xte, yte)
    np.testing.assert_array_equal(res.predictions, m.golden_predict(Xte))
    assert (res.n_survivors == 1).all()     # exactly one matching path


def test_selective_precharge_saves_evaluations():
    Xtr, ytr, Xte, yte = load_split("diabetes")
    m = DT2CAM(s=16, max_depth=10).fit(Xtr, ytr)
    with_sp = m.infer(Xte, selective_precharge=True)
    without = m.infer(Xte, selective_precharge=False)
    np.testing.assert_array_equal(with_sp.predictions, without.predictions)
    assert with_sp.active_evals.sum() < without.active_evals.sum()
    assert with_sp.mean_energy < without.mean_energy


def test_energy_accounting():
    Xtr, ytr, Xte, yte = load_split("iris")
    m = DT2CAM(s=16).fit(Xtr, ytr)
    res = m.infer(Xte)
    want = res.active_evals.astype(float) * DEFAULT_HW.e_row + DEFAULT_HW.e_mem
    np.testing.assert_allclose(res.energy_per_dec, want)


def test_latency_and_throughput_model():
    Xtr, ytr, Xte, yte = load_split("covid")
    m = DT2CAM(s=32, max_depth=DATASETS["covid"].max_depth).fit(Xtr, ytr)
    res = m.infer(Xte[:50])
    assert res.latency_s == pytest.approx(
        res.n_cwd * t_cwd(32) + DEFAULT_HW.t_mem)
    assert res.throughput_seq == pytest.approx(f_max(32) / res.n_cwd)
    # pipelined: one result every II=3 cycles (Fig 4 P/E/SA pipeline)
    assert res.throughput_pipe == pytest.approx(
        f_max(32) / DEFAULT_HW.pipeline_ii_cycles)
