"""Unified inference API: DT2CAM.infer backends, NonIdealSpec, engine
selection edge cases, input validation, and the expired-shim removal
errors (every removed shim must fail with an actionable message)."""
import numpy as np
import pytest

from repro.core import (DT2CAM, IDEAL, FeatureMismatch, NonIdealSpec,
                        TernaryLUT)
from repro.core.lut import CELL_MM
from repro.core.synth import synthesize
from repro.dt import load_split
from repro.kernels import select_engine, tcam_match

PAPER_DATASETS = ["iris", "cancer", "car"]


def _fitted(name, s=64):
    Xtr, ytr, Xte, yte = load_split(name)
    return DT2CAM(s=s, max_depth=8).fit(Xtr, ytr), Xte, yte


# --------------------------------------------------------------------------
# backend parity
# --------------------------------------------------------------------------
@pytest.mark.parametrize("dataset", PAPER_DATASETS)
def test_jax_backend_bit_exact_vs_sim_ideal(dataset):
    """Acceptance: backend='jax' matches backend='sim' predictions/energy
    bit-exactly on ideal hardware across the paper datasets."""
    m, Xte, yte = _fitted(dataset)
    r_sim = m.infer(Xte)                      # default backend='sim'
    r_jax = m.infer(Xte, backend="jax")
    np.testing.assert_array_equal(r_jax.predictions, r_sim.predictions)
    np.testing.assert_array_equal(r_jax.survivors, r_sim.survivors)
    np.testing.assert_array_equal(r_jax.n_survivors, r_sim.n_survivors)
    np.testing.assert_array_equal(r_jax.active_evals, r_sim.active_evals)
    np.testing.assert_array_equal(r_jax.energy_per_dec, r_sim.energy_per_dec)
    assert r_jax.latency_s == r_sim.latency_s
    assert r_jax.throughput_seq == r_sim.throughput_seq
    assert r_jax.throughput_pipe == r_sim.throughput_pipe


def test_backends_match_under_nonidealities_with_same_seed():
    """The SA-offset draw order matches and the kmax lowering is exact, so
    even non-ideal inference agrees across backends when seeded alike."""
    m, Xte, _ = _fitted("iris", s=16)
    spec = NonIdealSpec(p_sa0=0.02, p_sa1=0.01, sa_sigma=0.03, sigma_in=0.04)
    a = m.infer(Xte, nonideal=spec, rng=np.random.default_rng(7))
    b = m.infer(Xte, backend="jax", nonideal=spec, rng=np.random.default_rng(7))
    np.testing.assert_array_equal(a.predictions, b.predictions)
    np.testing.assert_array_equal(a.energy_per_dec, b.energy_per_dec)


def test_jax_backend_engine_passthrough_and_ref():
    m, Xte, _ = _fitted("iris", s=16)
    r_ref = m.infer(Xte, backend="jax", engine="ref")
    r_mxu = m.infer(Xte, backend="jax", engine="mxu")
    np.testing.assert_array_equal(r_ref.predictions, r_mxu.predictions)


def test_selective_precharge_off_matches_sim():
    m, Xte, _ = _fitted("iris", s=16)
    r_sim = m.infer(Xte, selective_precharge=False)
    r_jax = m.infer(Xte, backend="jax", selective_precharge=False)
    np.testing.assert_array_equal(r_jax.active_evals, r_sim.active_evals)
    np.testing.assert_array_equal(r_jax.energy_per_dec, r_sim.energy_per_dec)


def test_unknown_backend_rejected():
    m, Xte, _ = _fitted("iris", s=16)
    with pytest.raises(ValueError, match="backend"):
        m.infer(Xte, backend="tpu")


# --------------------------------------------------------------------------
# engine auto-selection edge cases
# --------------------------------------------------------------------------
def _layout(rng, rows=10, width=20, s=16, with_mm=False):
    cells = rng.integers(0, 3, size=(rows, width)).astype(np.int8)
    if with_mm:
        cells[0, 0] = CELL_MM
    lut = TernaryLUT(cells=cells,
                     classes=rng.integers(0, 3, rows).astype(np.int32),
                     n_classes=3,
                     feat_offsets=np.array([0, width]),
                     thresholds=[np.linspace(0, 1, width - 1)])
    return synthesize(lut, s, seed=0)


def test_auto_rejects_packed_when_s_not_mult_32():
    lay = _layout(np.random.default_rng(0), s=16)
    assert select_engine(lay.cells, 16, "auto") == "mxu"
    with pytest.raises(ValueError, match="packed"):
        select_engine(lay.cells, 16, "packed")


def test_auto_rejects_packed_when_cell_mm_present():
    lay = _layout(np.random.default_rng(1), s=32, with_mm=True)
    assert select_engine(lay.cells, 32, "auto") == "mxu"
    with pytest.raises(ValueError, match="CELL_MM|packed"):
        select_engine(lay.cells, 32, "packed")


def test_auto_picks_packed_when_legal():
    lay = _layout(np.random.default_rng(2), s=32)
    assert select_engine(lay.cells, 32, "auto") == "packed"


def test_unknown_engine_rejected():
    lay = _layout(np.random.default_rng(3), s=16)
    with pytest.raises(ValueError, match="unknown engine"):
        select_engine(lay.cells, 16, "warp")


def test_kmax_minus_one_forces_mismatch():
    """kmax = -1 means 'always mismatch' (the padded-row sentinel): the row
    never survives and is only ever evaluated in division 0."""
    rng = np.random.default_rng(4)
    lay = _layout(rng, rows=12, width=40, s=16)   # n_cwd > 1
    assert lay.n_cwd > 1
    xb = rng.integers(0, 2, size=(9, 40)).astype(np.uint8)
    xp = lay.pad_inputs(xb)
    rows = lay.cells.shape[0]
    km = np.full((rows, lay.n_cwd), -1, np.int32)
    surv, ev = tcam_match(lay.cells, xp, 16, kmax=np.asarray(km), engine="mxu")
    assert not np.asarray(surv).any()
    np.testing.assert_array_equal(np.asarray(ev), np.ones((9, rows), np.int32))


# --------------------------------------------------------------------------
# expired shims: every removed path raises an actionable, typed error
# --------------------------------------------------------------------------
def test_flat_nonideality_keywords_removed():
    m, Xte, _ = _fitted("iris", s=16)
    with pytest.raises(TypeError, match=r"removed.*NonIdealSpec"):
        m.infer(Xte, sigma_in=0.02, rng=np.random.default_rng(3))
    with pytest.raises(TypeError, match=r"p_sa0.*removed"):
        m.infer(Xte, p_sa0=0.1)
    # unknown kwargs still get the plain unexpected-keyword error
    with pytest.raises(TypeError, match="unexpected keyword"):
        m.infer(Xte, banana=1)
    # spec path unaffected
    res = m.infer(Xte, nonideal=NonIdealSpec(sigma_in=0.02),
                  rng=np.random.default_rng(3))
    assert res.predictions.shape == (len(Xte),)


def test_sim_result_tuple_unpacking_removed():
    m, Xte, _ = _fitted("iris", s=16)
    res = m.infer(Xte)
    with pytest.raises(TypeError, match="named fields"):
        preds, *_ = res
    with pytest.raises(TypeError, match="named fields"):
        iter(res)


# --------------------------------------------------------------------------
# input validation
# --------------------------------------------------------------------------
def test_infer_feature_mismatch_typed_error():
    m, Xte, _ = _fitted("iris", s=16)
    with pytest.raises(FeatureMismatch, match="expects 4"):
        m.infer(Xte[:, :3])
    with pytest.raises(ValueError, match="2-D"):
        m.infer(Xte[0])
    assert issubclass(FeatureMismatch, ValueError)


def test_nonideal_spec_validation():
    with pytest.raises(ValueError):
        NonIdealSpec(p_sa0=-0.1)
    with pytest.raises(ValueError):
        NonIdealSpec(p_sa0=0.6, p_sa1=0.6)
    assert IDEAL.is_ideal and not IDEAL.has_saf
    assert NonIdealSpec(p_sa1=0.1).has_saf
