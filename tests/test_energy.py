"""Analog hardware model anchors (paper Tables III/IV, Eqns 5-10)."""
import numpy as np
import pytest

from repro.core import (DEFAULT_HW, choose_tile_size, dynamic_range, f_max,
                        max_cells_per_row, t_cwd, t_opt)


TABLE_IV = [  # (D_cap limit, max cells/row, chosen S) — the paper's table
    (0.2, 154, 128),
    (0.3, 86, 64),
    (0.4, 53, 32),
    (0.5, 33, 32),
    (0.6, 21, 16),
]


@pytest.mark.parametrize("d_limit,max_cells,s", TABLE_IV)
def test_table_iv(d_limit, max_cells, s):
    assert max_cells_per_row(d_limit) == max_cells
    assert choose_tile_size(d_limit) == s


def test_f_max_1ghz_at_s128():
    """Paper: 'operating frequency for an array width of 128 is 1 GHz'."""
    assert f_max(128) == pytest.approx(1e9, rel=2e-3)


def test_dynamic_range_monotone_decreasing():
    d = [dynamic_range(n) for n in range(2, 512)]
    assert all(a > b for a, b in zip(d, d[1:]))


def test_t_opt_positive_and_decreasing_with_row_size():
    # more cells in parallel -> lower match-line R -> faster optimal sensing
    ts = [t_opt(s) for s in (16, 32, 64, 128)]
    assert all(t > 0 for t in ts)
    assert ts == sorted(ts, reverse=True)


def test_t_cwd_components():
    s = 64
    assert t_cwd(s) == pytest.approx(
        3 * DEFAULT_HW.tau_pchg + t_opt(s) + DEFAULT_HW.t_sa)


def test_f_max_bounded_by_t_mem():
    # very small arrays: T_mem dominates (Eqn 10's max(...))
    assert f_max(4) <= 1.0 / DEFAULT_HW.t_mem + 1e-6
