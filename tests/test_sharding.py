"""Sharding rules: logical->mesh mapping, divisibility fallback, dedup."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding import make_rules
from repro.launch.mesh import mesh_for_devices


@pytest.fixture(scope="module")
def rules():
    return make_rules(mesh_for_devices(1))


def test_basic_mapping(rules):
    assert rules.spec(("vocab", "embed")) == P("model", "data")
    assert rules.spec(("act_batch", None, "act_vocab")) == P(
        "data", None, "model")


def test_divisibility_fallback(rules):
    # 40 heads on a 16-way axis (phi3) -> replicated ... here axis size 1
    # divides everything; emulate a fake axis via table check instead
    spec = rules.spec(("act_heads",), (40,))
    assert spec in (P("model"), P())   # model size 1 divides


def test_duplicate_axis_dedup(rules):
    # one mesh axis may appear once: second use is dropped
    spec = rules.spec(("act_seq", "act_mlp"), (64, 64))
    axes = [a for a in spec if a is not None]
    assert len(axes) == len(set(map(str, axes)))


def test_trailing_nones_trimmed(rules):
    assert rules.spec((None, None)) == P()


def test_seq_parallel_flips_act_seq():
    mesh = mesh_for_devices(1)
    r = make_rules(mesh, seq_parallel=True)
    assert r.table["act_seq"] == "model"
    r2 = make_rules(mesh)
    assert r2.table["act_seq"] is None


def test_long_context_decode_rules():
    mesh = mesh_for_devices(1)
    r = make_rules(mesh, batch_divisible=False, seq_sharded_decode=True)
    assert r.table["act_batch"] is None
    assert r.table["cache_seq"] == ("data", "model")


def test_fallback_on_nondivisible_dim():
    """A dim of 7 on any >1 axis must drop the axis; on size-1 axes the spec
    survives."""
    mesh = mesh_for_devices(1)
    r = make_rules(mesh)
    spec = r.spec(("act_vocab",), (7,))
    # axis size 1 divides 7 -> kept
    assert spec == P("model")
