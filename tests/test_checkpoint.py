"""Checkpoint manager: atomicity, retention, validation, elastic restore."""
import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager


def _state(v=0.0):
    return {"params": {"w": jnp.full((4, 4), v)}, "step": jnp.int32(v)}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(10, _state(1.5))
    restored, step = mgr.restore(_state())
    assert step == 10
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.full((4, 4), 1.5))


def test_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _state(s))
    assert mgr._steps() == [3, 4]


def test_corrupt_checkpoint_skipped(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _state(1.0))
    mgr.save(2, _state(2.0))
    # corrupt the newest manifest
    with open(tmp_path / "step_000000002" / "manifest.json", "w") as f:
        f.write("{broken")
    assert mgr.latest_step() == 1
    restored, step = mgr.restore(_state())
    assert step == 1


def test_tmp_dirs_ignored_and_gcd(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    os.makedirs(tmp_path / "step_000000009.tmp")
    mgr.save(1, _state(1.0))
    assert mgr.latest_step() == 1
    assert not (tmp_path / "step_000000009.tmp").exists()  # GC'd


def test_crc_verification(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, _state(3.0))
    # flip a byte in the array file
    d = tmp_path / "step_000000005"
    arr = np.load(d / "arr_00000.npy")
    arr[0, 0] += 1
    np.save(d / "arr_00000.npy", arr)
    with pytest.raises(IOError):
        mgr.restore(_state(), verify_crc=True)


def test_elastic_restore_onto_new_sharding(tmp_path):
    """Restore works regardless of the target layout (device_put onto the
    structure's shardings) — the elastic-scaling path."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(7, _state(2.5))
    mesh = jax.make_mesh((1,), ("data",))
    sds = {
        "params": {"w": jax.ShapeDtypeStruct(
            (4, 4), jnp.float32,
            sharding=jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec("data")))},
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    restored, step = mgr.restore(sds)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.full((4, 4), 2.5))
