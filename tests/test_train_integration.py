"""End-to-end training integration: loss decreases on the planted-structure
stream; checkpoint/restore mid-run reproduces the exact trajectory."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_reduced
from repro.data import TokenPipeline
from repro.launch.mesh import mesh_for_devices
from repro.optim import AdamWConfig
from repro.runtime import FaultTolerantLoop
from repro.sharding import make_rules
from repro.train import build_train_step, init_train_state


def _setup(arch="olmo_1b", steps=60):
    cfg = get_reduced(arch)
    rules = make_rules(mesh_for_devices(1))
    opt = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=steps,
                      weight_decay=0.01)
    step = jax.jit(build_train_step(cfg, rules, opt))
    state = init_train_state(cfg, jax.random.PRNGKey(0), opt_cfg=opt)
    pipe = TokenPipeline(cfg, global_batch=8, seq_len=64, seed=0)
    return cfg, step, state, pipe


@pytest.mark.slow
def test_loss_decreases():
    cfg, step, state, pipe = _setup()
    losses = []
    for s in range(60):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(s).items()}
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    assert np.isfinite(losses).all()
    assert last < first - 0.2, (first, last)


def test_grad_accum_matches_single_batch():
    """accum=2 over a batch == accum=1 on the same batch (same grads up to
    fp tolerance) -> same loss trajectory start."""
    cfg = get_reduced("olmo_1b")
    rules = make_rules(mesh_for_devices(1))
    opt = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    pipe = TokenPipeline(cfg, global_batch=8, seq_len=32, seed=1)
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
    s1 = init_train_state(cfg, jax.random.PRNGKey(0), opt_cfg=opt)
    s2 = init_train_state(cfg, jax.random.PRNGKey(0), opt_cfg=opt)
    step1 = jax.jit(build_train_step(cfg, rules, opt, accum=1))
    step2 = jax.jit(build_train_step(cfg, rules, opt, accum=2))
    s1, m1 = step1(s1, batch)
    s2, m2 = step2(s2, batch)
    leaves1 = jax.tree.leaves(s1.params)
    leaves2 = jax.tree.leaves(s2.params)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=2e-2)
    for a, b in zip(leaves1, leaves2):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=0.1, atol=2e-3)


@pytest.mark.slow
def test_fault_tolerant_loop_with_real_model(tmp_path):
    cfg, step, state, pipe = _setup(steps=20)
    ckpt = CheckpointManager(str(tmp_path))
    calls = {"n": 0}

    def flaky_step(st, batch):
        calls["n"] += 1
        if calls["n"] == 7:
            raise RuntimeError("injected failure")
        return step(st, batch)

    loop = FaultTolerantLoop(
        flaky_step,
        lambda s: {k: jnp.asarray(v) for k, v in pipe.batch_at(s).items()},
        ckpt, ckpt_every=5, max_retries=2)
    state, end, hist = loop.run(state, 0, 12, log_every=0)
    assert end == 12
    assert all(np.isfinite(h["loss"]) for h in hist)
