"""Fault-tolerance runtime: retry-from-checkpoint, stragglers, preemption."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.runtime import FaultTolerantLoop, StragglerMonitor


def _mk_loop(tmp_path, step_fn, **kw):
    ckpt = CheckpointManager(str(tmp_path))
    return FaultTolerantLoop(
        step_fn, lambda s: {"x": np.float32(s)}, ckpt,
        ckpt_every=2, **kw), ckpt


def test_normal_run_checkpoints(tmp_path):
    def step(state, batch):
        return state + 1, {"loss": jnp.float32(1.0)}
    loop, ckpt = _mk_loop(tmp_path, step)
    state, step_idx, hist = loop.run(jnp.int32(0), 0, 6, log_every=0)
    assert step_idx == 6 and int(state) == 6
    assert ckpt.latest_step() == 6
    assert len(hist) == 6


def test_failure_recovers_from_checkpoint(tmp_path):
    calls = {"n": 0}

    def step(state, batch):
        calls["n"] += 1
        if calls["n"] == 5:                 # simulated node failure
            raise RuntimeError("device lost")
        return state + 1, {"loss": jnp.float32(1.0)}

    loop, ckpt = _mk_loop(tmp_path, step)
    state, step_idx, _ = loop.run(jnp.int32(0), 0, 8, log_every=0)
    assert step_idx == 8
    assert int(state) == 8                  # replay restored the lost step
    assert loop.retries == 0                # reset after success


def test_nonfinite_loss_triggers_restore(tmp_path):
    calls = {"n": 0}

    def step(state, batch):
        calls["n"] += 1
        loss = jnp.float32(np.nan if calls["n"] == 4 else 1.0)
        return state + 1, {"loss": loss}

    loop, ckpt = _mk_loop(tmp_path, step)
    state, step_idx, _ = loop.run(jnp.int32(0), 0, 6, log_every=0)
    assert step_idx == 6 and int(state) == 6


def test_bounded_retries(tmp_path):
    def step(state, batch):
        raise RuntimeError("always broken")
    loop, _ = _mk_loop(tmp_path, step, max_retries=2)
    with pytest.raises(RuntimeError):
        loop.run(jnp.int32(0), 0, 4, log_every=0)


def test_preemption_checkpoints_and_exits(tmp_path):
    def step(state, batch):
        return state + 1, {"loss": jnp.float32(1.0)}
    loop, ckpt = _mk_loop(tmp_path, step)
    state, i, _ = loop.run(jnp.int32(0), 0, 3, log_every=0)
    loop.preempted = True                    # SIGTERM flag
    state, j, _ = loop.run(state, i, 10, log_every=0)
    assert j == i                            # exited immediately
    assert ckpt.latest_step() == i


def test_straggler_monitor():
    m = StragglerMonitor(factor=2.0)
    for _ in range(10):
        assert not m.observe(1.0)
    assert m.observe(5.0)
    assert m.stragglers == 1
