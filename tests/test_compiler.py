"""DT-HW compiler end-to-end (paper Fig 2: Iris) + all-dataset pipeline."""
import numpy as np
import pytest

from repro.core import DT2CAM, compile_tree, train_tree
from repro.dt import DATASETS, load_split


def test_iris_fig2_regime():
    """Real embedded Iris: the compiled LUT lands at the paper's Table V
    size (9 x 12) with default fit params."""
    spec = DATASETS["iris"]
    Xtr, ytr, Xte, yte = load_split("iris")
    m = DT2CAM(s=16, max_depth=spec.max_depth).fit(Xtr, ytr)
    rows, width = m.compiled.lut_shape
    assert (rows, width) == spec.paper_lut
    res = m.infer(Xte)
    assert res.accuracy(yte) == m.golden_accuracy(Xte, yte)
    assert res.accuracy(yte) >= 0.75


@pytest.mark.parametrize("name", ["haberman", "car", "cancer", "diabetes"])
def test_lut_shape_regime(name):
    """Synthetic Table II stand-ins land within ~2x of the paper's Table V
    LUT shapes (regime match; see DESIGN.md §7)."""
    spec = DATASETS[name]
    Xtr, ytr, Xte, yte = load_split(name)
    tree = train_tree(Xtr, ytr, max_depth=spec.max_depth,
                      max_leaves=spec.max_leaves)
    c = compile_tree(tree, 64)
    pr, pw = spec.paper_lut
    rows, width = c.lut_shape
    assert 0.4 * pr <= rows <= 2.2 * pr, (name, c.lut_shape)
    assert 0.3 * pw <= width <= 3.0 * pw, (name, c.lut_shape)


def test_eqn2_total_bits():
    Xtr, ytr, _, _ = load_split("iris")
    tree = train_tree(Xtr, ytr, max_depth=5)
    c = compile_tree(tree, 16)
    assert c.lut.n_total == c.lut.n_rows * c.lut.width     # Eqn 2
