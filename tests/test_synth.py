"""ReCAM synthesizer mapping step (paper §II.C.1, Table V)."""
import math

import numpy as np
import pytest

from repro.core import CELL_1, CELL_X, TernaryLUT, synthesize
from repro.core.lut import CELL_0


def _lut(rows, width, n_classes=2, seed=0):
    rng = np.random.default_rng(seed)
    cells = rng.integers(0, 3, size=(rows, width)).astype(np.int8)
    return TernaryLUT(
        cells=cells,
        classes=rng.integers(0, n_classes, rows).astype(np.int32),
        n_classes=n_classes,
        feat_offsets=np.array([0, width]),
        thresholds=[np.linspace(0, 1, width - 1)],
    )


# Table V: LUT size -> tiles at each S (paper's datasets)
TABLE_V = [
    ((9, 12), {16: (1, 1), 32: (1, 1), 64: (1, 1), 128: (1, 1)}),       # Iris
    ((120, 123), {16: (8, 8), 32: (4, 4), 64: (2, 2), 128: (1, 1)}),    # Diabetes
    ((93, 71), {16: (6, 5), 32: (3, 3), 64: (2, 2), 128: (1, 1)}),      # Haberman
    ((76, 20), {16: (5, 2), 32: (3, 1), 64: (2, 1), 128: (1, 1)}),      # Car
    ((23, 52), {16: (2, 4), 32: (1, 2), 64: (1, 1), 128: (1, 1)}),      # Cancer
    ((8475, 3580), {16: (530, 224), 32: (265, 112), 64: (133, 56),
                    128: (67, 28)}),                                     # Credit
    ((191, 150), {16: (12, 10), 32: (6, 5), 64: (3, 3), 128: (2, 2)}),  # Titanic
    ((441, 146), {16: (28, 10), 32: (14, 5), 64: (7, 3), 128: (4, 2)}), # Covid
]


@pytest.mark.parametrize("lut_size,expect", TABLE_V)
def test_table_v_tile_counts(lut_size, expect):
    """N_rwd = ceil(rows/S), N_cwd = ceil((width+1)/S) reproduce Table V for
    the paper's LUT shapes at every S."""
    rows, width = lut_size
    for s, (n_rwd, n_cwd) in expect.items():
        assert math.ceil(rows / s) == n_rwd, (lut_size, s)
        assert math.ceil((width + 1) / s) == n_cwd, (lut_size, s)


@pytest.mark.parametrize("rows,width,s", [(9, 12, 16), (120, 123, 32),
                                          (23, 52, 64), (191, 150, 128)])
def test_synthesize_layout(rows, width, s):
    lut = _lut(rows, width)
    lay = synthesize(lut, s)
    assert lay.n_rwd == math.ceil(rows / s)
    assert lay.n_cwd == math.ceil((width + 1) / s)
    assert lay.cells.shape == (lay.n_rwd * s, lay.n_cwd * s)
    # decoder column: LUT rows match the padded '0' input bit, rogue rows
    # store '1' (forced mismatch)
    np.testing.assert_array_equal(lay.cells[:rows, 0], CELL_0)
    np.testing.assert_array_equal(lay.cells[rows:, 0], CELL_1)
    # padding is don't-care
    assert (lay.cells[:rows, 1 + width:] == CELL_X).all()
    # rogue classes are valid class ids
    assert lay.classes.min() >= 0 and lay.classes.max() < lut.n_classes


def test_pad_inputs_decoder_bit():
    lut = _lut(5, 7)
    lay = synthesize(lut, 16)
    xb = np.ones((3, 7), np.uint8)
    xp = lay.pad_inputs(xb)
    assert xp.shape == (3, 16)
    assert (xp[:, 0] == 0).all()            # decoder bit
    np.testing.assert_array_equal(xp[:, 1:8], xb)
    assert (xp[:, 8:] == 0).all()


def test_area_positive_and_scales():
    small = synthesize(_lut(9, 12), 16).area_m2()
    big = synthesize(_lut(441, 146), 16).area_m2()
    assert 0 < small < big
