"""Hardware non-idealities (paper §II.C.2, Table I, Fig 7)."""
import numpy as np
import pytest

from repro.core import DT2CAM, NonIdealSpec, apply_saf, noisy_inputs
from repro.core.lut import CELL_0, CELL_1, CELL_MM, CELL_X
from repro.dt import load_split


def test_saf_zero_prob_identity():
    cells = np.random.default_rng(0).integers(0, 3, (50, 40)).astype(np.int8)
    np.testing.assert_array_equal(apply_saf(cells, 0.0, 0.0), cells)


def test_saf_table_i_reachable_states():
    """Table I: SA0 can turn 0/1 -> x; SA1 can create {LRS,LRS} (=CELL_MM)."""
    rng = np.random.default_rng(1)
    cells = np.full((200, 200), CELL_0, np.int8)
    sa0 = apply_saf(cells, 0.5, 0.0, rng)
    assert set(np.unique(sa0)) <= {CELL_0, CELL_X}
    sa1 = apply_saf(cells, 0.0, 0.5, rng)
    assert CELL_MM in np.unique(sa1)          # {LRS, LRS}
    x_cells = np.full((200, 200), CELL_X, np.int8)
    sa1x = apply_saf(x_cells, 0.0, 0.5, rng)
    assert set(np.unique(sa1x)) <= {CELL_X, CELL_0, CELL_1, CELL_MM}


def test_saf_accuracy_degrades_with_rate():
    Xtr, ytr, Xte, yte = load_split("cancer")
    m = DT2CAM(s=32, max_depth=8).fit(Xtr, ytr)
    base = m.infer(Xte).accuracy(yte)
    rng = np.random.default_rng(2)
    accs = [np.mean([m.infer(Xte, nonideal=NonIdealSpec(p_sa0=p, p_sa1=p),
                             rng=np.random.default_rng(100 + i)).accuracy(yte)
                     for i in range(3)]) for p in (0.001, 0.05)]
    assert accs[0] >= accs[1] - 0.02          # higher defect rate hurts more
    assert base >= accs[1]


def test_input_noise_changes_encoding_not_catastrophically():
    Xtr, ytr, Xte, yte = load_split("diabetes")
    m = DT2CAM(s=64, max_depth=8).fit(Xtr, ytr)
    base = m.infer(Xte).accuracy(yte)
    small = m.infer(Xte, nonideal=NonIdealSpec(sigma_in=0.001)).accuracy(yte)
    assert abs(base - small) < 0.1


def test_sa_variability_monotone_in_sigma():
    Xtr, ytr, Xte, yte = load_split("cancer")
    m = DT2CAM(s=32, max_depth=8).fit(Xtr, ytr)
    base = m.infer(Xte).accuracy(yte)
    hi = np.mean([m.infer(Xte, nonideal=NonIdealSpec(sa_sigma=0.1),
                          rng=np.random.default_rng(i)).accuracy(yte)
                  for i in range(3)])
    assert hi <= base + 1e-9
