"""Hardware non-idealities (paper §II.C.2, Table I, Fig 7)."""
import warnings

import numpy as np
import pytest

from repro.core import (DT2CAM, NonIdealSpec, apply_saf, apply_saf_mask,
                        noisy_inputs, sample_saf)
from repro.core.lut import CELL_0, CELL_1, CELL_MM, CELL_X
from repro.dt import load_split


def test_saf_zero_prob_identity():
    cells = np.random.default_rng(0).integers(0, 3, (50, 40)).astype(np.int8)
    np.testing.assert_array_equal(apply_saf(cells, 0.0, 0.0), cells)


def test_saf_table_i_reachable_states():
    """Table I: SA0 can turn 0/1 -> x; SA1 can create {LRS,LRS} (=CELL_MM)."""
    rng = np.random.default_rng(1)
    cells = np.full((200, 200), CELL_0, np.int8)
    sa0 = apply_saf(cells, 0.5, 0.0, rng)
    assert set(np.unique(sa0)) <= {CELL_0, CELL_X}
    sa1 = apply_saf(cells, 0.0, 0.5, rng)
    assert CELL_MM in np.unique(sa1)          # {LRS, LRS}
    x_cells = np.full((200, 200), CELL_X, np.int8)
    sa1x = apply_saf(x_cells, 0.0, 0.5, rng)
    assert set(np.unique(sa1x)) <= {CELL_X, CELL_0, CELL_1, CELL_MM}


def test_saf_accuracy_degrades_with_rate():
    Xtr, ytr, Xte, yte = load_split("cancer")
    m = DT2CAM(s=32, max_depth=8).fit(Xtr, ytr)
    base = m.infer(Xte).accuracy(yte)
    rng = np.random.default_rng(2)
    accs = [np.mean([m.infer(Xte, nonideal=NonIdealSpec(p_sa0=p, p_sa1=p),
                             rng=np.random.default_rng(100 + i)).accuracy(yte)
                     for i in range(3)]) for p in (0.001, 0.05)]
    assert accs[0] >= accs[1] - 0.02          # higher defect rate hurts more
    assert base >= accs[1]


def test_input_noise_changes_encoding_not_catastrophically():
    Xtr, ytr, Xte, yte = load_split("diabetes")
    m = DT2CAM(s=64, max_depth=8).fit(Xtr, ytr)
    base = m.infer(Xte).accuracy(yte)
    small = m.infer(Xte, nonideal=NonIdealSpec(sigma_in=0.001)).accuracy(yte)
    assert abs(base - small) < 0.1


def test_saf_tie_break_is_50_50():
    """When both independent SA draws fire on one element, a fair coin picks
    the winner (the documented behavior).  With p_sa0 = p_sa1 = 0.5:
    P(sa0) = P(only fire0) + P(both)/2 = 0.25 + 0.125 = 0.375 — a sharp pin
    distinguishing the coin from either 'sa0 wins' (0.5) or
    'sa1 wins' (0.25)."""
    mask = sample_saf((400, 400), 0.5, 0.5, np.random.default_rng(3))
    for arr in (mask.sa0_r1, mask.sa1_r1, mask.sa0_r2, mask.sa1_r2):
        assert 0.36 < arr.mean() < 0.39
    # an element is never stuck both ways
    assert not (mask.sa0_r1 & mask.sa1_r1).any()
    assert not (mask.sa0_r2 & mask.sa1_r2).any()


def test_saf_missing_rng_removed():
    """The silent default_rng(0) fallback expired: a non-trivial draw with
    no rng is a TypeError naming the fix; zero-probability shortcuts and
    explicit-rng calls never needed randomness and must stay working."""
    cells = np.full((16, 16), CELL_0, np.int8)
    with pytest.raises(TypeError, match=r"apply_saf\(\) requires an explicit"):
        apply_saf(cells, 0.5, 0.0)
    with pytest.raises(TypeError,
                       match=r"noisy_inputs\(\) requires an explicit"):
        noisy_inputs(np.zeros((4, 4)), 0.1)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        apply_saf(cells, 0.5, 0.0, np.random.default_rng(0))
        apply_saf(cells, 0.0, 0.0)
        noisy_inputs(np.zeros((4, 4)), 0.1, np.random.default_rng(0))
        noisy_inputs(np.zeros((4, 4)), 0.0)


def test_apply_saf_mask_idempotent_and_write_through():
    rng = np.random.default_rng(4)
    cells = rng.integers(0, 4, (60, 40)).astype(np.int8)
    mask = sample_saf(cells.shape, 0.1, 0.1, rng)
    once = apply_saf_mask(cells, mask)
    np.testing.assert_array_equal(once, apply_saf_mask(once, mask))
    # faults are persistent chip state: writing different content goes
    # through the same stuck elements; healthy cells take the new value
    other = rng.integers(0, 4, (60, 40)).astype(np.int8)
    out = apply_saf_mask(other, mask)
    healthy = ~mask.any_fault
    np.testing.assert_array_equal(out[healthy], other[healthy])
    with pytest.raises(ValueError):
        apply_saf_mask(cells[:10], mask)


def test_sa_variability_monotone_in_sigma():
    Xtr, ytr, Xte, yte = load_split("cancer")
    m = DT2CAM(s=32, max_depth=8).fit(Xtr, ytr)
    base = m.infer(Xte).accuracy(yte)
    hi = np.mean([m.infer(Xte, nonideal=NonIdealSpec(sa_sigma=0.1),
                          rng=np.random.default_rng(i)).accuracy(yte)
                  for i in range(3)])
    assert hi <= base + 1e-9
