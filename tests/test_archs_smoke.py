"""Per-architecture smoke tests (assignment requirement): a REDUCED config of
the same family runs one forward/train step on CPU with correct shapes and
no NaNs, plus prefill->decode consistency with the full forward pass."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_reduced
from repro.data import make_batch
from repro.models import (decode_step, forward, init_cache, init_params,
                          loss_fn, prefill)


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    cfg = get_reduced(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v)
             for k, v in make_batch(cfg, 2, 32, step=0).items()}
    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(lambda p, b: loss_fn(p, cfg, b), has_aux=True)
    )(params, batch)
    assert np.isfinite(float(loss)), arch
    assert float(loss) > 0
    gn = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads)) ** 0.5
    assert np.isfinite(float(gn)), arch
    # output shapes: logits from forward
    kw = {}
    if cfg.frontend_tokens:
        kw["frontend"] = batch["patches"]
    if cfg.is_encdec:
        kw["frames"] = batch["frames"]
    logits = jax.jit(lambda p, t: forward(p, cfg, t, **kw))(
        params, batch["tokens"])
    want_seq = batch["tokens"].shape[1] + cfg.frontend_tokens
    assert logits.shape == (2, want_seq, cfg.vocab_size), arch
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32)))), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    """logits(prefill S tokens, then decode token S) == logits(forward over
    S+1 tokens)[:, -1] — validates every cache/state implementation.

    MoE archs use a generous capacity factor: token-drop patterns
    legitimately differ between full-sequence and prefill+decode routing;
    this test isolates cache/state correctness."""
    import dataclasses
    cfg = get_reduced(arch)
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)
    params = init_params(cfg, jax.random.PRNGKey(1))
    b, s = 2, 16
    batch = make_batch(cfg, b, s + 1, step=0)
    toks = jnp.asarray(batch["tokens"])          # (B, S+1[-frontend])
    kw = {}
    if cfg.frontend_tokens:
        kw["frontend"] = jnp.asarray(batch["patches"])
    if cfg.is_encdec:
        kw["frames"] = jnp.asarray(batch["frames"])

    full = forward(params, cfg, toks, **kw)       # (B, S_total, V)

    caches = init_cache(cfg, b, s + 8)
    pre_kw = dict(kw)
    if cfg.frontend_tokens:
        pre_kw = {"frontend": kw["frontend"]}
    if cfg.is_encdec:
        pre_kw = {"frames": kw["frames"]}
    _, caches = prefill(params, cfg, toks[:, :-1], caches, **pre_kw)
    pos = jnp.int32(toks.shape[1] - 1 + cfg.frontend_tokens)
    got, _ = decode_step(params, cfg, toks[:, -1:], caches, pos)

    np.testing.assert_allclose(
        np.asarray(got[:, 0]).astype(np.float32),
        np.asarray(full[:, -1]).astype(np.float32),
        rtol=5e-2, atol=5e-2)   # bf16 compute tolerance
