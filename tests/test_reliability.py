"""Reliability layer: BIST, spare-row repair, redundancy voting, canary and
the serving circuit breaker (chip-health tentpole)."""
import dataclasses

import numpy as np
import pytest

from repro.core import DT2CAM, NonIdealSpec, compile_tree
from repro.core.encode import encode_inputs
from repro.core.lut import CELL_0, CELL_1, CELL_MM, CELL_X
from repro.core.nonideal import SAFMask, apply_saf_mask, sample_saf
from repro.core.simulate import simulate
from repro.dt import load_split
from repro.reliability import (
    BreakerState,
    CircuitBreaker,
    ReplicatedServer,
    behavior_changed_rows,
    majority_vote,
    make_canary,
    march_probes,
    repair_layout,
    row_signatures,
    row_utilization,
    run_bist,
)
from repro.serve import ServeConfig, TCAMServer


@pytest.fixture(scope="module")
def iris_model():
    Xtr, ytr, Xte, yte = load_split("iris")
    m = DT2CAM(s=16, max_depth=5, spare_rows=24).fit(Xtr, ytr)
    return m, Xtr, ytr, Xte, yte


def _fault_chip(layout, p, seed):
    mask = sample_saf(layout.cells.shape, p, p, np.random.default_rng(seed))
    cells = apply_saf_mask(layout.cells, mask)
    return dataclasses.replace(layout, cells=cells), mask


# --------------------------------------------------------------------------
# behavior signatures & march probes (pure logic)
# --------------------------------------------------------------------------
def test_row_signatures_dead_and_literals():
    used = 5
    cells = np.array([
        [CELL_0, CELL_0, CELL_1, CELL_X, CELL_X],    # alive: 0@1, 1@2
        [CELL_1, CELL_X, CELL_X, CELL_X, CELL_X],    # decoder 1 -> dead
        [CELL_0, CELL_X, CELL_MM, CELL_X, CELL_X],   # CELL_MM -> dead
    ], np.int8)
    dead, zeros, ones = row_signatures(cells, used)
    assert list(dead) == [False, True, True]
    assert list(zeros[0]) == [True, False, False, False]
    assert list(ones[0]) == [False, True, False, False]


def test_behavior_changed_rows_ignores_invisible_faults():
    used = 4
    intent = np.array([[CELL_0, CELL_0, CELL_X, CELL_X]], np.int8)
    same = intent.copy()
    # decoder 0 -> X is invisible: queries always carry '0' there
    same[0, 0] = CELL_X
    assert not behavior_changed_rows(intent, same, used)[0]
    flipped = intent.copy()
    flipped[0, 1] = CELL_1                    # literal flip: visible
    assert behavior_changed_rows(intent, flipped, used)[0]


def test_march_probes_shapes_and_decoder_pinned():
    row = np.array([CELL_0, CELL_1, CELL_0, CELL_X], np.int8)
    probes = march_probes(row, 4)
    assert probes.shape == (4, 4)
    assert (probes[:, 0] == 0).all()          # decoder bit never probed '1'
    assert list(probes[0]) == [0, 1, 0, 0]    # stored word
    # each walking probe flips exactly one body bit of the stored word
    for i in range(1, 4):
        assert (probes[i] != probes[0]).sum() == 1


# --------------------------------------------------------------------------
# BIST detection & coverage
# --------------------------------------------------------------------------
def test_bist_clean_chip_reports_nothing(iris_model):
    m, *_ = iris_model
    lay = m.compiled.layout
    rep = run_bist(lay.cells, lay.cells, used=1 + lay.width,
                   n_rows=lay.cells.shape[0])
    assert rep.n_defective == 0
    assert rep.coverage(np.zeros(lay.cells.shape[0], bool)) == 1.0


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_bist_coverage_at_2pct(iris_model, seed):
    """Acceptance bar: >= 90% of behavior-changing rows detected at
    p_sa0 = p_sa1 = 2%."""
    m, *_ = iris_model
    lay = m.compiled.layout
    used = 1 + lay.width
    flay, _ = _fault_chip(lay, 0.02, seed)
    rep = run_bist(flay.cells, lay.cells, used=used,
                   n_rows=lay.cells.shape[0])
    changed = behavior_changed_rows(lay.cells, flay.cells, used)
    assert rep.coverage(changed) >= 0.90
    # BIST never cries wolf on behaviorally-identical rows
    assert not (rep.detected & ~changed).any()


def test_bist_catches_rogue_row_come_alive():
    """A dead-intent spare whose faults bring it alive with several
    1-literals evades intent-derived walking probes; the readback (M2/M3)
    elements must catch it."""
    used = 6
    intent = np.full((1, 8), CELL_X, np.int8)
    intent[0, 0] = CELL_1                     # dead rogue row
    actual = intent.copy()
    actual[0, 0] = CELL_0                     # decoder fault: alive
    actual[0, 2] = CELL_1                     # needs THREE 1s at once
    actual[0, 3] = CELL_1
    actual[0, 4] = CELL_1
    rep = run_bist(actual, intent, used=used, n_rows=0)
    assert rep.detected[0]


# --------------------------------------------------------------------------
# spare-row repair
# --------------------------------------------------------------------------
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_repair_recovers_accuracy_at_2pct(iris_model, seed):
    """Acceptance bar: post-repair accuracy within 1% of the ideal chip."""
    m, Xtr, ytr, Xte, yte = iris_model
    lay, lut = m.compiled.layout, m.compiled.lut
    used = 1 + lay.width
    flay, mask = _fault_chip(lay, 0.02, seed)
    rep = run_bist(flay.cells, lay.cells, used=used,
                   n_rows=lay.cells.shape[0])
    prio = row_utilization(lay, encode_inputs(lut, Xtr))
    rlay, rintent, rr = repair_layout(
        flay, lay.cells, mask, rep.defective_rows, priority=prio
    )
    xb = encode_inputs(lut, Xte)
    acc_ideal = (simulate(lay, xb).predictions == yte).mean()
    acc_rep = (simulate(rlay, xb).predictions == yte).mean()
    assert acc_rep >= acc_ideal - 0.01
    # repair is honest: the reported chip is the intent seen through the mask
    expect = apply_saf_mask(rintent, mask)
    expect[:, used:] = CELL_X                 # masked columns are OFF-OFF
    np.testing.assert_array_equal(rlay.cells, expect)
    # a re-test against the updated intent comes back clean
    rep2 = run_bist(rlay.cells, rintent, used=used,
                    n_rows=lay.cells.shape[0])
    assert not behavior_changed_rows(rintent, rlay.cells, used).any()
    assert rep2.n_defective == 0


def test_repair_degrades_gracefully_without_spares(iris_model):
    """No spare pool: repair must not raise — defective rows are reported
    as unrepaired and the report flags degradation."""
    m, *_ = iris_model
    base = compile_tree(m.compiled.tree, m.s, spare_rows=0)
    lay = base.layout
    # consume the natural tile-padding spares by marking them used
    intent = lay.cells.copy()
    intent[lay.n_rows:, 0] = CELL_0
    lay = dataclasses.replace(lay, cells=intent)
    flay, mask = _fault_chip(lay, 0.05, 0)
    used = 1 + lay.width
    rep = run_bist(flay.cells, intent, used=used, n_rows=lay.cells.shape[0])
    defect_lut = [r for r in rep.defective_rows if r < lay.n_rows]
    if not defect_lut:
        pytest.skip("no LUT-row defects drawn at this seed")
    _, _, rr = repair_layout(flay, intent, mask, rep.defective_rows)
    assert rr.unrepaired and rr.degraded
    assert rr.spares_used == 0


def test_repair_priority_orders_heavy_rows(iris_model):
    m, Xtr, *_ = iris_model
    lay, lut = m.compiled.layout, m.compiled.lut
    util = row_utilization(lay, encode_inputs(lut, Xtr))
    assert util.shape == (lay.cells.shape[0],)
    assert util.sum() > 0
    assert util[lay.n_rows:].sum() == 0       # spares serve no traffic


# --------------------------------------------------------------------------
# redundancy voting
# --------------------------------------------------------------------------
def test_majority_vote_plurality_and_ties():
    assert majority_vote([1, 1, 2]) == 1
    assert majority_vote([2, 2, 1, 1, 0]) == 1   # tie -> smallest class
    assert majority_vote([3]) == 3


def test_replicated_server_votes_out_single_chip_errors(iris_model):
    m, Xtr, ytr, Xte, yte = iris_model
    spec = NonIdealSpec(p_sa0=0.02, p_sa1=0.02)
    cfg = ServeConfig(engine="ref", background=False, max_batch=32)
    with ReplicatedServer(m.compiled, k=5, nonideal=spec,
                          rng=np.random.default_rng(11), config=cfg) as rs:
        voted = rs.serve(Xte)
        met = rs.metrics()
    acc_voted = np.mean([v.prediction for v in voted] == yte)
    assert met["k"] == 5 and met["requests"] == len(Xte)
    assert 0.0 <= met["disagreement_rate"] <= 1.0
    # each replica sampled its own chip: the k layouts are not all identical
    grids = [r._layout.cells.tobytes() for r in rs.replicas]
    assert len(set(grids)) > 1
    # voting beats the worst single chip
    per_chip = [np.mean([v.results[i].prediction for v in voted] == yte)
                for i in range(5)]
    assert acc_voted >= min(per_chip)
    for v in voted:
        assert v.n_answered == 5
        assert v.n_agree == sum(p == v.prediction
                                for p in v.votes if p is not None)


def test_replicated_server_requires_positive_k(iris_model):
    m, *_ = iris_model
    with pytest.raises(ValueError):
        ReplicatedServer(m.compiled, k=0)


# --------------------------------------------------------------------------
# canary & circuit breaker
# --------------------------------------------------------------------------
def test_canary_perfect_on_ideal_chip(iris_model):
    m, *_ = iris_model
    with TCAMServer(m.compiled,
                    config=ServeConfig(background=False)) as s:
        assert s.run_canary() == 1.0
        assert s.health()["state"] == BreakerState.HEALTHY


def test_make_canary_expected_matches_oracle(iris_model):
    m, *_ = iris_model
    lay = m.compiled.layout
    can = make_canary(lay, 16, np.random.default_rng(0))
    assert len(can) == 16
    assert (can.words[:, 0] == 0).all()       # reachable queries only
    preds = simulate(lay, can.words[:, 1:1 + lay.width]).predictions
    assert can.accuracy(preds) == 1.0


def test_breaker_state_machine():
    b = CircuitBreaker(threshold=0.9)
    assert not b.observe(0.95) and b.state == BreakerState.HEALTHY
    assert b.observe(0.5) and b.state == BreakerState.DEGRADED
    assert b.trips == 1
    b.recovered("repair", 0.97)
    assert b.state == BreakerState.REPAIRED and b.recovery == "repair"
    assert b.observe(0.3)                     # re-trip from repaired
    assert b.trips == 2
    b.failed(0.3)
    assert b.state == BreakerState.FAILED
    assert not b.observe(0.95)                # spontaneous recovery
    assert b.state == BreakerState.HEALTHY
    snap = b.snapshot()
    assert snap["trips"] == 2 and snap["last_accuracy"] == 0.95


def test_breaker_recovery_reentry_transitions():
    """Full recovery path re-enters steady state: FAILED -> repaired ->
    routine canary re-pass -> HEALTHY (and the same for scrub recovery),
    while the fallback engine stays sticky."""
    b = CircuitBreaker(threshold=0.9)
    assert b.observe(0.5) and b.state == BreakerState.DEGRADED
    b.failed(0.2)
    assert b.state == BreakerState.FAILED
    b.recovered("repair", 0.95)               # late repair out of FAILED
    assert b.state == BreakerState.REPAIRED and b.recovery == "repair"
    assert not b.observe(0.96)                # routine canary re-passes
    assert b.state == BreakerState.HEALTHY    # back in steady state
    assert b.trips == 1                       # re-entry is not a trip
    # scrub recovery takes the same re-entry path
    assert b.observe(0.3)
    b.recovered("scrub", 0.93)
    assert b.state == BreakerState.REPAIRED and b.recovery == "scrub"
    assert not b.observe(0.97)
    assert b.state == BreakerState.HEALTHY
    # fallback canaries pass on the fallback engine; they say nothing about
    # the primary path, so FALLBACK never silently re-enters HEALTHY
    assert b.observe(0.2)
    b.recovered("fallback_ref", 0.92)
    assert b.state == BreakerState.FALLBACK
    assert not b.observe(0.99)
    assert b.state == BreakerState.FALLBACK


def test_server_canary_trips_and_repairs(iris_model):
    """End-to-end degradation ladder: serving a faulty chip trips the
    breaker, which runs BIST + spare-row repair and re-votes the canary."""
    m, Xtr, ytr, Xte, yte = iris_model
    spec = NonIdealSpec(p_sa0=0.05, p_sa1=0.05)
    cfg = ServeConfig(background=False, max_batch=16, engine="ref",
                      canary_every_batches=1, canary_size=64)
    for seed in range(6):
        s = TCAMServer(m.compiled, nonideal=spec,
                       rng=np.random.default_rng(seed), config=cfg)
        tripped = s.run_canary() < cfg.canary_threshold
        if not tripped:
            s.close()
            continue
        s.serve(Xte)                          # batches trigger the canary
        h = s.health()
        assert h["breaker"]["trips"] >= 1
        assert h["state"] in (BreakerState.REPAIRED, BreakerState.FALLBACK,
                              BreakerState.FAILED)
        if h["state"] == BreakerState.REPAIRED:
            assert h["repair_attempts"] >= 1
            assert s.run_canary() >= cfg.canary_threshold
        rel = s.metrics()["reliability"]
        assert rel["breaker_trips"] == h["breaker"]["trips"]
        assert rel["canary_runs"] > 0
        s.close()
        return
    pytest.fail("no seed produced a tripping chip at p=5%")


def test_server_self_test_and_manual_repair(iris_model):
    m, Xtr, *_ = iris_model
    spec = NonIdealSpec(p_sa0=0.02, p_sa1=0.02)
    s = TCAMServer(m.compiled, nonideal=spec,
                   rng=np.random.default_rng(3),
                   config=ServeConfig(background=False, engine="ref"))
    rep = s.self_test()
    h0 = s.health()
    assert h0["spares_total"] > 0
    if rep.n_defective:
        report = s.repair(rep)
        assert s.metrics()["reliability"]["repairs"] == 1
        assert s.health()["spares_free"] <= h0["spares_free"]
        # post-repair self-test is clean
        assert s.self_test().n_defective == 0
    s.close()


def test_repair_without_saf_mask_raises(iris_model):
    m, *_ = iris_model
    with TCAMServer(m.compiled,
                    config=ServeConfig(background=False)) as s:
        with pytest.raises(RuntimeError, match="stuck-at"):
            s.repair()
