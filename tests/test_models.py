"""Model substrate correctness: flash attention vs naive, chunked
mamba/rwkv vs exact recurrence, MoE dispatch semantics, prefill/decode
consistency."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models.attention import decode_attention, flash_attention
from repro.models.config import ModelConfig
from repro.models import mamba as M
from repro.models import rwkv as R
from repro.models.moe import capacity, moe_ffn


def _naive_attention(q, k, v, causal=True, window=0, prefix_len=0):
    b, sq, h, hd = q.shape
    _, sk, kv, _ = k.shape
    g = h // kv
    qf = q.astype(jnp.float32).reshape(b, sq, kv, g, hd)
    s = jnp.einsum("bqkgh,bskh->bqkgs", qf, k.astype(jnp.float32))
    s = s * hd ** -0.5
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        c = kpos <= qpos
        if prefix_len:
            c |= kpos < prefix_len
        mask &= c
    if window:
        w = kpos > qpos - window
        if prefix_len:
            w |= kpos < prefix_len
        mask &= w
    s = jnp.where(mask[None, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqkgs,bskh->bqkgh", p, v.astype(jnp.float32))
    return o.reshape(b, sq, h, hd)


@pytest.mark.parametrize("sq,h,kv,causal,window,prefix", [
    (64, 4, 4, True, 0, 0),
    (64, 8, 2, True, 0, 0),       # GQA
    (128, 4, 1, True, 0, 0),      # MQA
    (64, 4, 2, True, 16, 0),      # SWA
    (64, 4, 4, False, 0, 0),      # encoder
    (64, 4, 2, True, 0, 24),      # paligemma prefix
])
def test_flash_vs_naive(sq, h, kv, causal, window, prefix):
    rng = np.random.default_rng(sq + h)
    q = jnp.asarray(rng.standard_normal((2, sq, h, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, sq, kv, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, sq, kv, 16)), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          prefix_len=prefix, q_chunk=16, kv_chunk=32)
    want = _naive_attention(q, k, v, causal, window, prefix)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_decode_attention_matches_last_row_of_flash():
    rng = np.random.default_rng(3)
    S = 32
    q = jnp.asarray(rng.standard_normal((2, S, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, S, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, S, 2, 16)), jnp.float32)
    full = _naive_attention(q, k, v, causal=True)
    slot_pos = jnp.arange(S, dtype=jnp.int32)
    dec = decode_attention(q[:, -1:], k, v, slot_pos, jnp.int32(S - 1))
    np.testing.assert_allclose(np.asarray(dec)[:, 0], np.asarray(full)[:, -1],
                               rtol=2e-4, atol=2e-4)


def _mamba_cfg():
    return ModelConfig(name="t", family="ssm", n_layers=1, d_model=32,
                       n_heads=4, n_kv_heads=4, head_dim=8, d_ff=64,
                       vocab_size=64, pattern=("mamba+mlp",), ssm_state=4)


def test_mamba_chunked_equals_stepwise():
    """Chunked selective scan == token-by-token recurrence."""
    cfg = _mamba_cfg()
    rng = np.random.default_rng(0)
    from repro.models.params import init_params
    params = init_params(cfg, jax.random.PRNGKey(0))["blocks"]["mamba+mlp"]
    p = jax.tree.map(lambda a: a[0], params)
    x = jnp.asarray(rng.standard_normal((2, M.CHUNK * 2, 32)), jnp.float32)
    full = M.mamba_mixer(x, p, cfg)
    state = M.init_mamba_state(cfg, 2, jnp.float32)
    outs = []
    for t in range(x.shape[1]):
        o, state = M.mamba_mixer(x[:, t:t + 1], p, cfg, state=state,
                                 return_state=True)
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step),
                               rtol=2e-3, atol=2e-3)


def _rwkv_cfg():
    return ModelConfig(name="t", family="ssm", n_layers=1, d_model=32,
                       n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64,
                       vocab_size=64, pattern=("rwkv+cmix",),
                       rwkv_head_dim=16, rope_theta=0.0)


def test_rwkv_chunked_equals_stepwise():
    cfg = _rwkv_cfg()
    rng = np.random.default_rng(1)
    from repro.models.params import init_params
    params = init_params(cfg, jax.random.PRNGKey(1))["blocks"]["rwkv+cmix"]
    p = jax.tree.map(lambda a: a[0], params)
    x = jnp.asarray(0.5 * rng.standard_normal((2, R.CHUNK * 2, 32)),
                    jnp.float32)
    full = R.rwkv_mixer(x, p, cfg)
    xa = jnp.zeros((2, 32), jnp.float32)
    sst = jnp.zeros((2, 2, 16, 16), jnp.float32)
    outs = []
    for t in range(x.shape[1]):
        o, (xa, sst) = R.rwkv_mixer(x[:, t:t + 1], p, cfg, state=(xa, sst),
                                    return_state=True)
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step),
                               rtol=5e-3, atol=5e-3)


def test_rwkv_channel_mix_stepwise():
    cfg = _rwkv_cfg()
    from repro.models.params import init_params
    params = init_params(cfg, jax.random.PRNGKey(2))["blocks"]["rwkv+cmix"]
    p = jax.tree.map(lambda a: a[0], params)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((2, 8, 32)), jnp.float32)
    full = R.rwkv_channel_mix(x, p, cfg)
    st = jnp.zeros((2, 32), jnp.float32)
    outs = []
    for t in range(8):
        o, st = R.rwkv_channel_mix(x[:, t:t + 1], p, cfg, state=st,
                                   return_state=True)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(full),
                               np.asarray(jnp.concatenate(outs, 1)),
                               rtol=1e-5, atol=1e-5)


def _moe_cfg(groups=1):
    return ModelConfig(name="t", family="moe", n_layers=1, d_model=16,
                       n_heads=2, n_kv_heads=2, head_dim=8, d_ff=32,
                       vocab_size=64, pattern=("attn+moe",), n_experts=4,
                       experts_per_token=2, moe_d_ff=32, moe_groups=groups,
                       capacity_factor=8.0)   # large cf: no drops


def test_moe_equals_dense_reference():
    """With no capacity drops, scatter/gather MoE == explicit per-expert
    dense computation."""
    cfg = _moe_cfg()
    from repro.models.params import init_params
    p = jax.tree.map(lambda a: a[0],
                     init_params(cfg, jax.random.PRNGKey(3))["blocks"]["attn+moe"])
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal((2, 8, 16)), jnp.float32)
    got = moe_ffn(x, p, cfg)
    # dense reference
    xt = x.reshape(-1, 16)
    logits = xt @ p["w_router"]
    gates = jax.nn.softmax(logits, -1)
    w, idx = jax.lax.top_k(gates, 2)
    w = w / w.sum(-1, keepdims=True)
    all_out = []
    for e in range(4):
        h = jax.nn.silu(xt @ p["w_gate"][e]) * (xt @ p["w_up"][e])
        all_out.append(h @ p["w_down"][e])
    all_out = jnp.stack(all_out, 1)            # (T, E, D)
    want = jnp.einsum("tk,tkd->td", w,
                      jnp.take_along_axis(all_out, idx[..., None], 1))
    np.testing.assert_allclose(np.asarray(got).reshape(-1, 16),
                               np.asarray(want), rtol=2e-4, atol=2e-4)


def test_moe_groups_invariant():
    """moe_groups changes scheduling, not results (modulo per-group capacity,
    generous cf => identical)."""
    from repro.models.params import init_params
    cfg1, cfg2 = _moe_cfg(1), _moe_cfg(2)
    p = jax.tree.map(lambda a: a[0],
                     init_params(cfg1, jax.random.PRNGKey(5))["blocks"]["attn+moe"])
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.standard_normal((2, 8, 16)), jnp.float32)
    np.testing.assert_allclose(np.asarray(moe_ffn(x, p, cfg1)),
                               np.asarray(moe_ffn(x, p, cfg2)),
                               rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_tokens():
    cfg = dataclasses.replace(_moe_cfg(), capacity_factor=0.25)
    from repro.models.params import init_params
    p = jax.tree.map(lambda a: a[0],
                     init_params(cfg, jax.random.PRNGKey(7))["blocks"]["attn+moe"])
    rng = np.random.default_rng(8)
    x = jnp.asarray(rng.standard_normal((2, 32, 16)), jnp.float32)
    y = moe_ffn(x, p, cfg)
    assert bool(jnp.all(jnp.isfinite(y)))
    # capacity formula
    assert capacity(cfg, 64) == max(8, -(-int(0.25 * 64 * 2 / 4) // 8) * 8)
