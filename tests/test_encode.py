"""Ternary adaptive encoding (paper §II.A.4, Fig 1) + property tests."""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import (CELL_0, CELL_1, CELL_X, span_code, unary_code,
                        encode_table, encode_inputs)
from repro.core.encode import feature_thresholds, _range_index
from repro.core.reduce import (CMP_BETWEEN, CMP_GT, CMP_LE, CMP_NONE,
                               RuleTable)
from repro.core.lut import bitplanes


def _code_str(c):
    return "".join({CELL_0: "0", CELL_1: "1", CELL_X: "x"}[int(v)] for v in c)


class TestFig1:
    """The paper's worked example: thresholds {0.8, 1.5, 1.65, 1.75}."""

    def test_exclusive_range_codes(self):
        assert _code_str(unary_code(1, 5)) == "00001"   # (-inf, 0.8]
        assert _code_str(unary_code(2, 5)) == "00011"   # (0.8, 1.5]
        assert _code_str(unary_code(3, 5)) == "00111"   # (1.5, 1.65]
        assert _code_str(unary_code(4, 5)) == "01111"   # (1.65, 1.75]
        assert _code_str(unary_code(5, 5)) == "11111"   # (1.75, inf)

    def test_union_range_08_165(self):
        # (0.8, 1.65] spans ranges 2..3 -> 00x11 (XOR(00011,00111)=00100)
        assert _code_str(span_code(2, 3, 5)) == "00x11"

    def test_union_range_15_inf(self):
        # (1.5, +inf) spans ranges 3..5 -> xx111
        assert _code_str(span_code(3, 5, 5)) == "xx111"

    def test_le_08(self):
        assert _code_str(span_code(1, 1, 5)) == "00001"

    def test_between_165_175(self):
        assert _code_str(span_code(4, 4, 5)) == "01111"


def _random_rule_table(rng, rows=8, feats=3, n_th=4):
    """Random reduced table with thresholds drawn from a shared grid (as a
    real tree produces)."""
    grid = np.sort(rng.choice(np.linspace(0.05, 0.95, 19), n_th,
                              replace=False))
    comp = rng.integers(0, 4, size=(rows, feats)).astype(np.int8)
    th1 = np.full((rows, feats), np.nan)
    th2 = np.full((rows, feats), np.nan)
    for r in range(rows):
        for f in range(feats):
            c = comp[r, f]
            if c == CMP_LE or c == CMP_GT:
                th1[r, f] = rng.choice(grid)
            elif c == CMP_BETWEEN:
                lo, hi = np.sort(rng.choice(len(grid), 2, replace=False))
                th1[r, f], th2[r, f] = grid[lo], grid[hi]
    classes = rng.integers(0, 3, size=rows).astype(np.int32)
    return RuleTable(comp, th1, th2, classes, 3)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_encoding_preserves_match_semantics(seed):
    """PROPERTY (the paper's bijectivity claim): for any reduced rule table
    and any input, the encoded-LUT ternary match equals direct rule
    evaluation."""
    rng = np.random.default_rng(seed)
    table = _random_rule_table(rng)
    lut = encode_table(table)
    X = rng.uniform(-0.2, 1.2, size=(32, table.n_features))
    want = table.row_matches(X)                      # (B, rows) direct
    xbits = encode_inputs(lut, X)
    is0, is1 = bitplanes(lut.cells)
    mism = xbits @ is0.T + (1 - xbits) @ is1.T
    got = mism == 0
    np.testing.assert_array_equal(got, want)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_adaptive_precision_width(seed):
    """Eqn 1: n_i = T_i + 1 bits per feature."""
    rng = np.random.default_rng(seed)
    table = _random_rule_table(rng)
    lut = encode_table(table)
    ths = feature_thresholds(table)
    widths = np.diff(lut.feat_offsets)
    for i, th in enumerate(ths):
        assert widths[i] == th.size + 1


def test_input_encoding_is_exact_range_code():
    th = np.array([0.8, 1.5, 1.65, 1.75])
    # value == threshold lands in the range it closes (inclusive ']')
    assert _range_index(np.array([0.8]), th)[0] == 1
    assert _range_index(np.array([0.81]), th)[0] == 2
    assert _range_index(np.array([1.75]), th)[0] == 4
    assert _range_index(np.array([1.76]), th)[0] == 5
