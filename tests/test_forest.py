"""Forest compiler + sharded multi-bank execution.

Acceptance (ISSUE): a 25-tree sklearn RandomForest compiled with
``compile_forest`` must reproduce ``RandomForestClassifier.predict``
bit-exactly on the numpy ref path, and the jax engines must match per
engine; a single-tree forest must agree with the single-tree path; the
modelled aggregate dec/s must grow monotonically with bank count; and
forest-mode serving must survive per-bank BIST/repair with spare-row
survivors resolving to the right vote entries.
"""
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

import repro
from repro.core import DT2CAM, NonIdealSpec
from repro.dt import load_split
from repro.forest import (
    CompiledForest,
    compile_forest,
    forest_infer_ref,
    plan_forest,
    train_forest,
)

sklearn = pytest.importorskip("sklearn")
from sklearn.ensemble import RandomForestClassifier  # noqa: E402

PAPER_DATASETS = ["cancer", "car"]


@pytest.fixture(scope="module", params=PAPER_DATASETS)
def rf_case(request):
    Xtr, ytr, Xte, yte = load_split(request.param)
    rf = RandomForestClassifier(
        n_estimators=25, max_depth=8, random_state=0
    ).fit(Xtr, ytr)
    forest = compile_forest(rf, s=128)
    return request.param, rf, forest, Xte, yte


# --------------------------------------------------------------------------
# sklearn parity: ref path
# --------------------------------------------------------------------------
def test_sklearn_forest_parity_ref(rf_case):
    name, rf, forest, Xte, yte = rf_case
    assert isinstance(forest, CompiledForest)
    assert forest.n_banks == 25
    res = forest_infer_ref(forest, Xte)
    np.testing.assert_array_equal(res.predictions, rf.predict(Xte))
    # soft-vote scores match predict_proba up to fp aggregation order
    np.testing.assert_allclose(res.score, rf.predict_proba(Xte),
                               rtol=0, atol=1e-12)


def test_sklearn_forest_parity_banked_engine(rf_case):
    name, rf, forest, Xte, yte = rf_case
    ref = forest_infer_ref(forest, Xte)
    ex = repro.ForestExecutor(forest, engine="banked")
    res = ex.infer(Xte)
    np.testing.assert_array_equal(res.predictions, rf.predict(Xte))
    np.testing.assert_array_equal(res.survivors, ref.survivors)
    np.testing.assert_array_equal(res.active_evals, ref.active_evals)


def test_sklearn_forest_parity_mxu_engine():
    # one dataset, small batch: the vmapped Pallas kernel runs in interpret
    # mode on CPU and is slow
    Xtr, ytr, Xte, yte = load_split("cancer")
    rf = RandomForestClassifier(
        n_estimators=5, max_depth=6, random_state=1
    ).fit(Xtr, ytr)
    forest = compile_forest(rf, s=128)
    Xq = Xte[:32]
    ref = forest_infer_ref(forest, Xq)
    res = repro.ForestExecutor(forest, engine="mxu").infer(Xq)
    np.testing.assert_array_equal(res.predictions, rf.predict(Xq))
    np.testing.assert_array_equal(res.survivors, ref.survivors)
    np.testing.assert_array_equal(res.active_evals, ref.active_evals)


# --------------------------------------------------------------------------
# single-tree forest == single-tree path
# --------------------------------------------------------------------------
def _single_tree_agrees(seed: int, n: int) -> None:
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, 5))
    y = (X[:, 0] + 0.5 * X[:, 2] > 0).astype(np.int64)
    model = DT2CAM(s=32, max_depth=6).fit(X, y)
    forest = compile_forest([model.compiled.tree], s=32)
    assert forest.n_banks == 1
    single = model.infer(X)
    res = forest_infer_ref(forest, X)
    np.testing.assert_array_equal(res.predictions, single.predictions)
    np.testing.assert_array_equal(res.survivors[0], single.survivors)
    np.testing.assert_array_equal(res.active_evals[0], single.active_evals)


def test_single_tree_forest_equals_single_tree_deterministic():
    for seed in (0, 1, 2):
        _single_tree_agrees(seed, 80)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 9999), n=st.integers(30, 120))
def test_single_tree_forest_equals_single_tree_property(seed, n):
    _single_tree_agrees(seed, n)


# --------------------------------------------------------------------------
# plan + figures
# --------------------------------------------------------------------------
def test_plan_shapes_and_figures_monotone():
    Xtr, ytr, _, _ = load_split("cancer")
    trees = train_forest(Xtr, ytr, n_trees=4, max_depth=8, seed=0)
    rates = []
    for n in (1, 2, 4):
        forest = compile_forest(trees[:n], s=128)
        plan = plan_forest(forest)
        assert sorted(
            int(i) for g in plan.groups for i in g.bank_ids
        ) == list(range(n))
        for g in plan.groups:
            assert g.r_pad % g.s == 0 and (g.r_pad & (g.r_pad - 1)) == 0
            assert g.cells.shape == (g.n_banks, g.r_pad, g.d_pad * g.s)
        figs = repro.forest_figures(forest.layouts)
        assert figs["aggregate"]["n_banks"] == n
        rates.append(figs["aggregate"]["decs_pipe"])
    assert rates[0] < rates[1] < rates[2]


def test_compile_forest_validation():
    Xtr, ytr, Xte, _ = load_split("cancer")
    trees = train_forest(Xtr, ytr, n_trees=2, max_depth=4, seed=0)
    with pytest.raises(ValueError, match="vote"):
        compile_forest(trees, s=64, vote="plurality")
    forest = compile_forest(trees, s=64)
    with pytest.raises(repro.FeatureMismatch, match="expects"):
        forest_infer_ref(forest, Xte[:, :-1])


# --------------------------------------------------------------------------
# serving: forest mode, repair, degradation
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def served_forest():
    Xtr, ytr, Xte, yte = load_split("cancer")
    trees = train_forest(Xtr, ytr, n_trees=6, max_depth=6, seed=0)
    forest = compile_forest(trees, s=128, spare_rows=4)
    return forest, Xte


def test_forest_serving_matches_ref(served_forest):
    forest, Xte = served_forest
    ref = forest_infer_ref(forest, Xte[:48])
    cfg = repro.ServeConfig(engine="banked", max_batch=16, background=False)
    srv = repro.TCAMServer(forest, config=cfg)
    assert srv.warmup() > 0
    futs = [srv.submit(x) for x in Xte[:48]]
    srv.drain()
    preds = np.array([f.result().prediction for f in futs])
    np.testing.assert_array_equal(preds, ref.predictions)
    assert srv.health()["mode"] == "forest"
    m = srv.metrics()
    assert m["modelled_mdecs_pipe"] > m["modelled_mdecs_ensemble"]
    with pytest.raises(repro.FeatureMismatch, match="expects"):
        srv.submit(Xte[0, :-1])


def test_forest_repair_keeps_serving(served_forest):
    """Per-bank BIST + spare-row repair: post-repair survivors land on
    spare rows, which must resolve through the physical->LUT row map to
    the original vote entries (not crash or mis-vote)."""
    forest, Xte = served_forest
    ref = forest_infer_ref(forest, Xte[:48])
    cfg = repro.ServeConfig(engine="banked", max_batch=16, background=False)
    srv = repro.TCAMServer(
        forest, config=cfg,
        nonideal=NonIdealSpec(p_sa0=0.01, p_sa1=0.01),
        rng=np.random.default_rng(11),
    )
    bists = srv.self_test()
    assert len(bists) == forest.n_banks
    assert sum(b.defective_rows.size for b in bists) > 0
    reports = srv.repair(bists)
    assert sum(r.rows_repaired for r in reports) > 0
    futs = [srv.submit(x) for x in Xte[:48]]
    srv.drain()
    preds = np.array([f.result().prediction for f in futs])
    # the repaired chip votes like the ideal forest on (almost) all inputs;
    # unrepairable banks drop out of the vote rather than poisoning it
    assert (preds == ref.predictions).mean() > 0.9
    health = srv.health()
    assert health["n_banks"] == forest.n_banks
    assert 1 <= health["banks_enabled"] <= forest.n_banks


def test_disable_bank_degrades_gracefully(served_forest):
    forest, Xte = served_forest
    cfg = repro.ServeConfig(engine="banked", max_batch=16, background=False)
    srv = repro.TCAMServer(forest, config=cfg)
    enabled = np.ones(forest.n_banks, bool)
    enabled[0] = False
    ref = forest_infer_ref(forest, Xte[:32], enabled=enabled)
    srv.disable_bank(0)
    futs = [srv.submit(x) for x in Xte[:32]]
    srv.drain()
    preds = np.array([f.result().prediction for f in futs])
    np.testing.assert_array_equal(preds, ref.predictions)
    for b in range(1, forest.n_banks):
        if b < forest.n_banks - 1:
            srv.disable_bank(b)
    with pytest.raises(RuntimeError, match="last voting bank"):
        srv.disable_bank(forest.n_banks - 1)


# --------------------------------------------------------------------------
# blessed top-level API
# --------------------------------------------------------------------------
def test_top_level_api_resolves():
    missing = [n for n in repro.__all__ if not hasattr(repro, n)]
    assert missing == []
    assert repro.compile_forest is compile_forest
    assert repro.TCAMServer.__module__.startswith("repro.serve")
    with pytest.raises(AttributeError):
        repro.not_a_public_name
