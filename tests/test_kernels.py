"""Pallas kernels vs pure-jnp oracle vs numpy simulator: shape/dtype sweeps
and hypothesis property tests (interpret=True on CPU)."""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

import jax
import jax.numpy as jnp

from repro.core.lut import CELL_MM, bitplanes
from repro.core.synth import TCAMLayout, synthesize
from repro.core import TernaryLUT
from repro.kernels import (pack_bits, sa_kmax, tcam_infer, tcam_match,
                           tcam_match_ref, tcam_match_packed_ref)


def _random_layout(rng, rows, width, s, with_mm=False):
    cells = rng.integers(0, 3, size=(rows, width)).astype(np.int8)
    if with_mm:
        mm = rng.random((rows, width)) < 0.02
        cells[mm] = CELL_MM
    lut = TernaryLUT(cells=cells,
                     classes=rng.integers(0, 4, rows).astype(np.int32),
                     n_classes=4,
                     feat_offsets=np.array([0, width]),
                     thresholds=[np.linspace(0, 1, width - 1)])
    return synthesize(lut, s, seed=int(rng.integers(1 << 30)))


SWEEP = [
    # rows, width, s, batch
    (9, 12, 16, 7),
    (40, 70, 32, 33),
    (120, 123, 64, 130),
    (50, 200, 128, 16),
    (300, 40, 32, 64),
]


@pytest.mark.parametrize("rows,width,s,b", SWEEP)
@pytest.mark.parametrize("engine", ["mxu", "packed"])
def test_kernel_matches_oracle(rows, width, s, b, engine):
    if engine == "packed" and s % 32:
        pytest.skip("packed needs S % 32 == 0")
    rng = np.random.default_rng(rows * 7 + s)
    lay = _random_layout(rng, rows, width, s)
    xb = rng.integers(0, 2, size=(b, width)).astype(np.uint8)
    xp = lay.pad_inputs(xb)
    is0, is1 = bitplanes(lay.cells)
    want_s, want_e = tcam_match_ref(jnp.asarray(xp), jnp.asarray(is0),
                                    jnp.asarray(is1), s)
    got_s, got_e = tcam_match(lay.cells, xp, s, engine=engine)
    np.testing.assert_array_equal(np.asarray(got_s), np.asarray(want_s))
    np.testing.assert_array_equal(np.asarray(got_e), np.asarray(want_e))


def test_mm_cells_force_mxu_and_mismatch():
    rng = np.random.default_rng(5)
    lay = _random_layout(rng, 20, 30, 32, with_mm=True)
    xb = rng.integers(0, 2, size=(8, 30)).astype(np.uint8)
    xp = lay.pad_inputs(xb)
    with pytest.raises(ValueError):
        tcam_match(lay.cells, xp, 32, engine="packed")
    is0, is1 = bitplanes(lay.cells)
    want_s, _ = tcam_match_ref(jnp.asarray(xp), jnp.asarray(is0),
                               jnp.asarray(is1), 32)
    got_s, _ = tcam_match(lay.cells, xp, 32, engine="auto")   # falls back
    np.testing.assert_array_equal(np.asarray(got_s), np.asarray(want_s))


def test_pack_bits_roundtrip_semantics():
    rng = np.random.default_rng(7)
    bits = rng.integers(0, 2, size=(5, 64)).astype(np.uint8)
    packed = np.asarray(pack_bits(jnp.asarray(bits)))
    for r in range(5):
        for w in range(2):
            word = int(packed[r, w])
            for i in range(32):
                assert ((word >> i) & 1) == bits[r, 32 * w + i]


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 99999))
def test_property_kernel_equals_simulator(seed):
    """PROPERTY: kernels reproduce the numpy analog simulator (survivors,
    active evaluations, energy) for random layouts and inputs."""
    from repro.core.simulate import simulate
    rng = np.random.default_rng(seed)
    rows = int(rng.integers(4, 60))
    width = int(rng.integers(4, 90))
    s = int(rng.choice([16, 32, 64]))
    lay = _random_layout(rng, rows, width, s)
    xb = rng.integers(0, 2, size=(int(rng.integers(1, 40)), width)).astype(
        np.uint8)
    res = simulate(lay, xb)
    jres = tcam_infer(lay, xb)
    np.testing.assert_array_equal(jres.predictions, res.predictions)
    np.testing.assert_array_equal(jres.n_survivors, res.n_survivors)
    np.testing.assert_array_equal(jres.active_evals, res.active_evals)
    np.testing.assert_array_equal(jres.energy_per_dec, res.energy_per_dec)


def test_sa_kmax_parity_with_analog_decision():
    """kmax lowering == analog V_ml > V_ref + offset decision."""
    from repro.core.simulate import (_division_mismatches, sense_voltage)
    rng = np.random.default_rng(11)
    lay = _random_layout(rng, 30, 45, 32)
    xb = rng.integers(0, 2, size=(25, 45)).astype(np.uint8)
    xp = lay.pad_inputs(xb)
    offsets = rng.normal(0, 0.05, size=(lay.cells.shape[0], lay.n_cwd))
    km = sa_kmax(lay, offsets)
    got_s, got_e = tcam_match(lay.cells, xp, 32, kmax=np.asarray(km),
                              engine="mxu")
    counts, n_eff = _division_mismatches(lay, xp)
    v_ml = sense_voltage(counts, n_eff[None, None, :], 32)
    v_fm = sense_voltage(np.zeros(lay.n_cwd), n_eff, 32)
    v_1mm = sense_voltage(np.ones(lay.n_cwd), n_eff, 32)
    v_ref = 0.5 * (v_fm + v_1mm)
    match = v_ml > (v_ref[None, None, :] + offsets[None, :, :])
    prior = np.cumprod(np.concatenate(
        [np.ones((25, match.shape[1], 1), bool), match[:, :, :-1]], 2), 2
    ).astype(bool)
    np.testing.assert_array_equal(
        np.asarray(got_s).astype(bool), prior[:, :, -1] & match[:, :, -1])
    np.testing.assert_array_equal(np.asarray(got_e), prior.sum(2))
