"""Optional-``hypothesis`` shim so the suite collects in a clean env.

Test modules import ``given``/``settings``/``st`` from here instead of from
``hypothesis`` directly.  With hypothesis installed (see requirements-dev.txt)
the real decorators are re-exported; without it the property tests collect as
individual skips (reason: "hypothesis not installed") while every example
based test in the same module still runs.
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only in clean envs
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            # Fresh zero-arg function: @given normally supplies the params,
            # so the wrapped signature must not leak into pytest's fixture
            # resolution.
            def skipper():
                pytest.skip("hypothesis not installed (see requirements-dev.txt)")

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper

        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    class _StrategyStub:
        """Accepts any strategy construction (st.integers(...), st.lists(...))
        at collection time; the results are never executed."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()
