"""Model lifecycle subsystem: registry round-trips, delta reprogramming at
write-pulse resolution, endurance/wear-leveling, and zero-downtime shadow
promotion on the serving engine."""
import threading

import numpy as np
import pytest

import repro
from repro.core import (
    CELL_1,
    CELL_X,
    DEFAULT_HW,
    DT2CAM,
    FeatureMismatch,
    HardwareParams,
    NonIdealSpec,
    encode_inputs,
    simulate,
    write_energy,
)
from repro.dt import load_split
from repro.lifecycle import (
    LifecycleManager,
    ModelRegistry,
    WearTracker,
    content_hash,
    plan_delta,
    plan_forest_delta,
    plan_full,
    wear_level_rows,
)
from repro.serve import ServeConfig, TCAMServer


@pytest.fixture(scope="module")
def retrained_pair():
    """v1 on clean iris, v2 retrained on noise-perturbed features."""
    Xtr, ytr, Xte, yte = load_split("iris")
    rng = np.random.default_rng(7)
    Xtr2 = Xtr + rng.normal(0, 1, Xtr.shape) * 0.1 * Xtr.std(0, keepdims=True)
    v1 = DT2CAM(s=16, max_depth=5).fit(Xtr, ytr)
    v2 = DT2CAM(s=16, max_depth=5).fit(Xtr2, ytr)
    return v1, v2, (Xtr, ytr, Xte, yte)


def _sync_cfg(**kw) -> ServeConfig:
    base = dict(background=False, engine="ref", max_batch=16, min_bucket=8)
    base.update(kw)
    return ServeConfig(**base)


# --------------------------------------------------------------------------
# registry: content addressing, round-trip, lineage
# --------------------------------------------------------------------------
def test_registry_tree_round_trip_and_idempotence(retrained_pair, tmp_path):
    v1, v2, (Xtr, ytr, Xte, _) = retrained_pair
    reg = ModelRegistry(tmp_path / "reg")
    r1 = reg.publish(v1.compiled, "iris", metadata={"gen": 1})
    r2 = reg.publish(v2.compiled, "iris", parents=[r1.version_id])
    assert len(reg) == 2 and r1.version_id in reg

    # idempotent: identical content maps to the same version
    again = reg.publish(v1.compiled, "iris")
    assert again.version_id == r1.version_id and len(reg) == 2

    # round-trip exact: every array, and the content hash, survive
    loaded = reg.load(r1.version_id)
    c = v1.compiled
    np.testing.assert_array_equal(loaded.layout.cells, c.layout.cells)
    np.testing.assert_array_equal(loaded.layout.class_bits,
                                  c.layout.class_bits)
    np.testing.assert_array_equal(loaded.tree.feature, c.tree.feature)
    np.testing.assert_array_equal(loaded.table.th1, c.table.th1)
    assert len(loaded.lut.thresholds) == len(c.lut.thresholds)
    for a, b in zip(loaded.lut.thresholds, c.lut.thresholds):
        np.testing.assert_array_equal(a, b)
    assert content_hash(loaded) == r1.content_hash
    # the reloaded model predicts identically
    xb = encode_inputs(loaded.lut, Xte)
    np.testing.assert_array_equal(
        simulate(loaded.layout, xb).predictions,
        simulate(c.layout, encode_inputs(c.lut, Xte)).predictions,
    )

    # index survives a fresh registry instance (JSON persistence)
    reg2 = ModelRegistry(tmp_path / "reg")
    assert len(reg2) == 2
    assert reg2.latest("iris").version_id == r2.version_id
    lineage = reg2.lineage(r2.version_id)
    assert [v.version_id for v in lineage] == [r2.version_id, r1.version_id]


def test_registry_forest_round_trip(tmp_path):
    Xtr, ytr, Xte, _ = load_split("iris")
    trees = repro.train_forest(Xtr, ytr, n_trees=3, max_depth=4, seed=0)
    forest = repro.compile_forest(trees, s=16)
    reg = ModelRegistry(tmp_path / "reg")
    rv = reg.publish(forest, "grove")
    assert rv.kind == "forest" and rv.n_banks == 3

    loaded = reg.load(rv.version_id)
    assert loaded.n_banks == 3 and loaded.vote == forest.vote
    for lb, fb in zip(loaded.banks, forest.banks):
        np.testing.assert_array_equal(lb.layout.cells, fb.layout.cells)
        assert (lb.proba is None) == (fb.proba is None)
    np.testing.assert_array_equal(
        repro.forest_infer_ref(loaded, Xte).predictions,
        repro.forest_infer_ref(forest, Xte).predictions,
    )
    assert content_hash(loaded) == rv.content_hash


def test_registry_rejects_bad_names_and_unknown_refs(retrained_pair,
                                                     tmp_path):
    v1, _, _ = retrained_pair
    reg = ModelRegistry(tmp_path / "reg")
    with pytest.raises(ValueError, match="may not contain"):
        reg.publish(v1.compiled, "bad:name")
    with pytest.raises(KeyError, match="parent"):
        reg.publish(v1.compiled, "m", parents=["m:doesnotexist"])
    with pytest.raises(KeyError, match="unknown version"):
        reg.load("m:doesnotexist")
    with pytest.raises(KeyError, match="no versions"):
        reg.latest("m")


# --------------------------------------------------------------------------
# delta planner: pulse maps, apply-verification, delta < full
# --------------------------------------------------------------------------
def test_plan_delta_reproduces_target_and_beats_full(retrained_pair):
    v1, v2, _ = retrained_pair
    o, n = v1.compiled.layout, v2.compiled.layout
    d = plan_delta(o.cells, n.cells, old_class_bits=o.class_bits,
                   new_class_bits=n.class_bits)
    f = plan_full(o.cells, n.cells, old_class_bits=o.class_bits,
                  new_class_bits=n.class_bits)
    # the acceptance criterion: strictly fewer cells written on a retrain
    assert 0 < d.n_cells_written < f.n_cells_written
    assert f.n_cells_written == f.shape[0] * f.shape[1]
    # applying the delta to the live grid lands exactly on the target
    from repro.lifecycle.delta import _pad_grid
    np.testing.assert_array_equal(d.apply(o.cells),
                                  _pad_grid(n.cells, d.shape))
    np.testing.assert_array_equal(f.apply(o.cells),
                                  _pad_grid(n.cells, f.shape))
    # pulse accounting: every changed cell needs 1..2 element pulses
    pulses = d.set_map + d.reset_map
    assert (pulses[d.rows, d.cols] >= 1).all()
    assert int((pulses > 0).sum()) == d.n_cells_changed
    assert d.n_pulses < f.n_pulses


def test_plan_delta_identical_grids_is_empty():
    cells = np.full((4, 8), CELL_X, np.int8)
    cells[:, 0] = CELL_1
    d = plan_delta(cells, cells)
    assert d.n_cells_written == 0 and d.n_pulses == 0
    assert d.rows_touched == 0
    np.testing.assert_array_equal(d.apply(cells), cells)


def test_plan_delta_aligns_mismatched_shapes():
    small = np.full((2, 4), CELL_1, np.int8)
    big = np.full((4, 6), CELL_X, np.int8)
    d = plan_delta(small, big)
    assert d.shape == (4, 6)
    # the 8 previously-programmed cells are released (RESET of element R1)
    assert d.n_cells_written == 8 and d.n_set == 0 and d.n_reset == 8


def test_write_energy_and_figures_model():
    hw = HardwareParams(e_set=2e-12, e_reset=3e-12, t_prog=5e-9,
                        endurance_writes=100.0)
    assert write_energy(10, 4, hw) == pytest.approx(10 * 2e-12 + 4 * 3e-12)
    cells = np.full((2, 4), CELL_X, np.int8)
    target = cells.copy()
    target[0, 1] = CELL_1            # 1 SET
    target[1, 2] = CELL_1            # 1 SET
    d = plan_delta(cells, target)
    figs = d.figures(hw)
    assert figs["set_pulses"] == 2 and figs["reset_pulses"] == 0
    assert figs["energy_j"] == pytest.approx(2 * 2e-12)
    assert figs["time_s"] == pytest.approx(2 * 5e-9)
    assert figs["endurance_cycles_consumed"] == 2


def test_plan_forest_delta_handles_added_and_retired_banks():
    Xtr, ytr, _, _ = load_split("iris")
    trees = repro.train_forest(Xtr, ytr, n_trees=3, max_depth=4, seed=1)
    f2 = repro.compile_forest(trees[:2], s=16)
    f3 = repro.compile_forest(trees, s=16)

    plans = plan_forest_delta(f2, f3)
    assert len(plans) == 3
    # bank 2 is new: programmed from an erased array -> SET-only cell pulses
    assert plans[2].n_reset == 0 and plans[2].n_set > 0
    # shrinking retires bank 2: erased back to CELL_X -> RESET-only
    back = plan_forest_delta(f3, f2)
    assert back[2].n_set == 0 and back[2].n_reset > 0
    full_plans = plan_forest_delta(f2, f3, full=True)
    assert all(p.kind == "full" for p in full_plans)


# --------------------------------------------------------------------------
# wear: endurance ledger + wear-leveling row placement
# --------------------------------------------------------------------------
def test_wear_tracker_accumulates_and_flags_worn_cells():
    hw = HardwareParams(endurance_writes=3.0)
    w = WearTracker(hw=hw)
    a = np.full((2, 4), CELL_X, np.int8)
    b = a.copy()
    b[0, 1] = CELL_1
    there, back = plan_delta(a, b), plan_delta(b, a)
    for _ in range(2):               # two full program/erase cycles
        w.record(there)
        w.record(back)
    assert w.plans_recorded == 4
    assert w.total_pulses == 4 and w.max_cell_pulses == 4
    assert w.headroom() < 0          # past rated endurance
    assert w.worn_out()[0, 1] and w.worn_out().sum() == 1
    np.testing.assert_array_equal(w.worn_rows(), [0])
    snap = w.snapshot()
    assert snap["worn_cells"] == 1 and snap["endurance_writes"] == 3.0
    # grids grow automatically to the largest plan seen
    w.record(plan_delta(np.full((5, 9), CELL_X, np.int8),
                        np.full((5, 9), CELL_1, np.int8)))
    assert w.counts.shape == (5, 9)


def test_wear_level_rows_functional_equivalence(retrained_pair):
    v1, v2, (Xtr, _, Xte, _) = retrained_pair
    w = WearTracker()
    w.record(plan_full(np.zeros((0, 0), np.int8), v1.compiled.layout.cells))
    rm = wear_level_rows(v2.compiled.layout, v1.compiled.layout.cells, w)
    # same predictions, physically re-placed rows
    xb = encode_inputs(v2.compiled.lut, Xte)
    np.testing.assert_array_equal(
        simulate(rm.layout, xb).predictions,
        simulate(v2.compiled.layout, xb).predictions,
    )
    assert rm.row_map.shape[0] == v2.compiled.layout.n_rows
    assert len(np.unique(rm.row_map)) == rm.row_map.shape[0]  # injective


def test_wear_level_rows_respects_forbidden_rows(retrained_pair):
    v1, v2, (Xtr, _, Xte, _) = retrained_pair
    forbidden = [0, 3]
    rm = wear_level_rows(v2.compiled.layout, v1.compiled.layout.cells,
                         forbidden=forbidden)
    assert not set(forbidden) & set(rm.row_map.tolist())
    # forbidden rows carry a dead intent: decoder cell '1' mismatches all
    assert (rm.layout.cells[forbidden, 0] == CELL_1).all()
    xb = encode_inputs(v2.compiled.lut, Xte)
    np.testing.assert_array_equal(
        simulate(rm.layout, xb).predictions,
        simulate(v2.compiled.layout, xb).predictions,
    )
    with pytest.raises(ValueError, match="out of range"):
        wear_level_rows(v2.compiled.layout, v1.compiled.layout.cells,
                        forbidden=[10_000])
    n_phys = v2.compiled.layout.cells.shape[0]
    with pytest.raises(ValueError, match="cannot place"):
        wear_level_rows(v2.compiled.layout, v1.compiled.layout.cells,
                        forbidden=np.arange(n_phys))


def test_wear_level_composes_with_spare_row_repair():
    """The repair report's blocked_rows feed straight into the remapper."""
    from repro.core import apply_saf_mask, sample_saf
    from repro.reliability import repair_layout, run_bist
    import dataclasses as dc

    Xtr, ytr, Xte, _ = load_split("iris")
    c = repro.compile_tree(repro.train_tree(Xtr, ytr, max_depth=5),
                           16, spare_rows=12)
    lay = c.layout
    rng = np.random.default_rng(3)
    mask = sample_saf(lay.cells.shape, 0.03, 0.03, rng)
    faulty = dc.replace(lay, cells=apply_saf_mask(lay.cells, mask))
    bist = run_bist(faulty.cells, lay.cells, used=1 + lay.width,
                    n_rows=lay.cells.shape[0])
    _, _, report = repair_layout(faulty, lay.cells, mask,
                                 bist.defective_rows)
    blocked = report.blocked_rows
    assert blocked.size > 0
    rm = wear_level_rows(lay, lay.cells, forbidden=blocked)
    assert not set(blocked.tolist()) & set(rm.row_map.tolist())


# --------------------------------------------------------------------------
# serving: shadow slot, promotion gates, atomic swap, rollback
# --------------------------------------------------------------------------
def test_stage_mirror_promote_and_bit_exactness(retrained_pair):
    v1, v2, (Xtr, _, Xte, _) = retrained_pair
    srv = TCAMServer(v1.compiled, config=_sync_cfg())
    srv.stage(v2.compiled, mirror_fraction=1.0)
    assert srv.staged and srv.health()["candidate_staged"]

    n = len(Xte[:16])
    srv.submit_many(Xte[:n])
    srv.pump(force=True)
    lc = srv.metrics()["lifecycle"]
    assert lc["stages"] == 1
    assert lc["shadow_batches"] == 1 and lc["shadow_requests"] == n

    rep = srv.promote(min_shadow_batches=1, max_disagreement=1.0)
    assert rep.promoted and rep.reason == "promoted" and not srv.staged
    assert rep.canary_accuracy >= srv._config.canary_threshold
    assert srv.metrics()["lifecycle"]["promotions"] == 1

    # the promoted model is bit-exact against v2's reference sim path
    res = srv.serve(Xte)
    ref = simulate(v2.compiled.layout,
                   encode_inputs(v2.compiled.lut, Xte)).predictions
    np.testing.assert_array_equal([r.prediction for r in res], ref)
    srv.close()


def test_mirror_fraction_is_deterministic(retrained_pair):
    v1, v2, (_, _, Xte, _) = retrained_pair
    srv = TCAMServer(v1.compiled, config=_sync_cfg(max_batch=8))
    srv.stage(v2.compiled, mirror_fraction=0.25)
    for _ in range(8):               # 8 live batches -> exactly 2 mirrored
        srv.submit_many(Xte[:8])
        srv.pump(force=True)
    lc = srv.metrics()["lifecycle"]
    assert lc["shadow_batches"] == 2
    assert lc["shadow_requests"] == 16
    srv.close()


def test_promote_gate_insufficient_shadow_keeps_candidate(retrained_pair):
    v1, v2, (_, _, Xte, _) = retrained_pair
    srv = TCAMServer(v1.compiled, config=_sync_cfg())
    srv.stage(v2.compiled, mirror_fraction=1.0)
    rep = srv.promote(min_shadow_batches=3)
    assert not rep.promoted and rep.reason == "insufficient_shadow"
    assert rep.staged and srv.staged          # still in the shadow slot
    assert srv.metrics()["lifecycle"]["promotion_failures"] == 0
    srv.close()


def test_promote_gate_disagreement_unstages(retrained_pair):
    v1, v2, (Xtr, _, Xte, _) = retrained_pair
    # v1 vs v2 genuinely disagree on some iris test rows; find them so the
    # gate deterministically sees drift
    p1 = simulate(v1.compiled.layout,
                  encode_inputs(v1.compiled.lut, Xte)).predictions
    p2 = simulate(v2.compiled.layout,
                  encode_inputs(v2.compiled.lut, Xte)).predictions
    drift = np.flatnonzero(p1 != p2)
    assert drift.size > 0, "fixture models must disagree somewhere"

    srv = TCAMServer(v1.compiled, config=_sync_cfg())
    srv.stage(v2.compiled, mirror_fraction=1.0)
    srv.submit_many(np.tile(Xte[drift], (2, 1))[:8])
    srv.pump(force=True)
    rep = srv.promote(max_disagreement=0.0)
    assert not rep.promoted and rep.reason == "disagreement"
    assert rep.disagreement_rate > 0.0
    assert not rep.staged and not srv.staged  # kicked out of the slot
    lc = srv.metrics()["lifecycle"]
    assert lc["promotion_failures"] == 1 and lc["promotions"] == 0
    # live model unchanged
    res = srv.serve(Xte[:8])
    np.testing.assert_array_equal([r.prediction for r in res], p1[:8])
    srv.close()


def test_promote_gate_candidate_canary_failure(retrained_pair):
    """A candidate staged onto badly faulty silicon fails its own canary and
    is rejected — the live model keeps serving."""
    v1, v2, (_, _, Xte, _) = retrained_pair
    srv = TCAMServer(
        v1.compiled,
        config=_sync_cfg(canary_threshold=0.99),
        nonideal=NonIdealSpec(p_sa0=0.10, p_sa1=0.10),
        rng=np.random.default_rng(11),
    )
    srv.stage(v2.compiled, mirror_fraction=1.0)
    srv.submit_many(Xte[:16])
    srv.pump(force=True)
    rep = srv.promote(min_shadow_batches=1, max_disagreement=1.0)
    assert not rep.promoted and rep.reason == "canary"
    assert rep.canary_accuracy < 0.99
    assert not srv.staged
    assert srv.metrics()["lifecycle"]["promotion_failures"] == 1
    srv.close()


def test_rollback_unstages_then_reverts(retrained_pair):
    v1, v2, (_, _, Xte, _) = retrained_pair
    srv = TCAMServer(v1.compiled, config=_sync_cfg())
    with pytest.raises(RuntimeError, match="nothing to roll back"):
        srv.rollback()

    srv.stage(v2.compiled, mirror_fraction=1.0)
    assert srv.rollback() == "unstaged" and not srv.staged

    srv.stage(v2.compiled, mirror_fraction=1.0)
    srv.submit_many(Xte[:16])
    srv.pump(force=True)
    assert srv.promote(max_disagreement=1.0).promoted
    assert srv.rollback() == "reverted"       # back on v1
    res = srv.serve(Xte)
    ref = simulate(v1.compiled.layout,
                   encode_inputs(v1.compiled.lut, Xte)).predictions
    np.testing.assert_array_equal([r.prediction for r in res], ref)
    assert srv.metrics()["lifecycle"]["rollbacks"] == 2
    srv.close()


def test_stage_validation_errors(retrained_pair):
    v1, v2, (Xtr, ytr, Xte, _) = retrained_pair
    srv = TCAMServer(v1.compiled, config=_sync_cfg())
    with pytest.raises(ValueError, match="mirror_fraction"):
        srv.stage(v2.compiled, mirror_fraction=0.0)
    wrong = DT2CAM(s=16, max_depth=3).fit(Xtr[:, :2], ytr)
    with pytest.raises(FeatureMismatch, match="candidate expects"):
        srv.stage(wrong.compiled)
    srv.stage(v2.compiled)
    with pytest.raises(RuntimeError, match="already staged"):
        srv.stage(v2.compiled)
    srv.close()

    trees = repro.train_forest(Xtr, ytr, n_trees=2, max_depth=3, seed=0)
    forest = repro.compile_forest(trees, s=16)
    fsrv = TCAMServer(forest, config=_sync_cfg())
    with pytest.raises(NotImplementedError, match="single-model only"):
        fsrv.stage(v2.compiled)
    with pytest.raises(RuntimeError, match="single-model only"):
        _ = fsrv.live_intent
    fsrv.close()


def test_stage_reuses_persistent_saf_mask(retrained_pair):
    """Same-shape candidate grids land on the same silicon: the persistent
    stuck-element mask carries over to the staged chip state."""
    v1, v2, _ = retrained_pair
    srv = TCAMServer(
        v1.compiled, config=_sync_cfg(),
        nonideal=NonIdealSpec(p_sa0=0.02, p_sa1=0.02),
        rng=np.random.default_rng(2),
    )
    assert v1.compiled.layout.cells.shape == v2.compiled.layout.cells.shape
    srv.stage(v2.compiled)
    assert srv._candidate.saf_mask is srv._saf_mask
    srv.close()


# --------------------------------------------------------------------------
# manager: registry -> plan -> shadow -> promote, with the wear ledger
# --------------------------------------------------------------------------
def test_manager_full_cycle(retrained_pair, tmp_path):
    v1, v2, (_, _, Xte, _) = retrained_pair
    reg = ModelRegistry(tmp_path / "reg")
    r1 = reg.publish(v1.compiled, "iris")
    r2 = reg.publish(v2.compiled, "iris", parents=[r1.version_id])

    srv = TCAMServer(v1.compiled, config=_sync_cfg())
    mgr = LifecycleManager(reg, srv, live_version=r1.version_id)
    assert mgr.live_version == r1.version_id
    assert mgr.wear.plans_recorded == 1       # initial full program

    plan = mgr.stage(r2.version_id, mirror_fraction=1.0)
    assert plan.kind == "delta" and srv.staged
    assert mgr.candidate_version == r2.version_id
    assert mgr.wear.plans_recorded == 2

    srv.submit_many(Xte[:16])
    srv.pump(force=True)
    rep = mgr.promote(min_shadow_batches=1, max_disagreement=1.0)
    assert rep.promoted
    assert mgr.live_version == r2.version_id
    assert mgr.candidate_version is None

    st = mgr.status()
    assert st["live_version"] == r2.version_id and not st["staged"]
    assert st["plans_executed"] == 2
    assert st["last_plan_figures"]["energy_j"] > 0
    assert st["wear"]["total_pulses"] > 0

    assert mgr.rollback() == "reverted"
    assert mgr.live_version == r1.version_id
    srv.close()


def test_manager_wear_leveled_stage_stays_functional(retrained_pair,
                                                     tmp_path):
    v1, v2, (_, _, Xte, _) = retrained_pair
    reg = ModelRegistry(tmp_path / "reg")
    r1 = reg.publish(v1.compiled, "iris")
    r2 = reg.publish(v2.compiled, "iris", parents=[r1.version_id])
    srv = TCAMServer(v1.compiled, config=_sync_cfg())
    mgr = LifecycleManager(reg, srv, live_version=r1.version_id)
    mgr.stage(r2.version_id, mirror_fraction=1.0, wear_level=True)
    srv.submit_many(Xte[:16])
    srv.pump(force=True)
    assert mgr.promote(max_disagreement=1.0).promoted
    # wear-leveled promotion still predicts exactly like v2's ideal path
    res = srv.serve(Xte)
    ref = simulate(v2.compiled.layout,
                   encode_inputs(v2.compiled.lut, Xte)).predictions
    np.testing.assert_array_equal([r.prediction for r in res], ref)
    srv.close()


def test_manager_requires_attachment(tmp_path, retrained_pair):
    v1, _, _ = retrained_pair
    reg = ModelRegistry(tmp_path / "reg")
    reg.publish(v1.compiled, "iris")
    mgr = LifecycleManager(reg)
    with pytest.raises(RuntimeError, match="no server attached"):
        mgr.stage("anything")
    with pytest.raises(ValueError, match="requires a server"):
        mgr.attach(None, "anything")


# --------------------------------------------------------------------------
# hot swap under live background load: zero dropped, zero errors
# --------------------------------------------------------------------------
def test_background_hot_swap_drops_nothing(retrained_pair):
    v1, v2, (_, _, Xte, _) = retrained_pair
    cfg = ServeConfig(engine="ref", max_batch=16, max_delay_s=0.001,
                      background=True)
    srv = TCAMServer(v1.compiled, config=cfg)
    rng = np.random.default_rng(0)
    idx = rng.integers(0, len(Xte), size=300)

    futs = []
    for i, x in enumerate(Xte[idx]):
        futs.append(srv.submit(x))
        if i == 100:
            srv.stage(v2.compiled, mirror_fraction=0.5)
        elif i == 200:
            # let the shadow slot see some mirrored batches first
            srv.drain(timeout=60.0)
            rep = srv.promote(min_shadow_batches=1, max_disagreement=1.0)
            assert rep.promoted, rep.reason
    srv.drain(timeout=60.0)

    assert all(f.done() for f in futs), "dropped requests across the swap"
    assert all(f.exception() is None for f in futs), "errored requests"
    p1 = simulate(v1.compiled.layout,
                  encode_inputs(v1.compiled.lut, Xte[idx])).predictions
    p2 = simulate(v2.compiled.layout,
                  encode_inputs(v2.compiled.lut, Xte[idx])).predictions
    served = np.array([f.result().prediction for f in futs])
    # every answer is bit-exact for the model generation that served it
    assert ((served == p1) | (served == p2)).all()
    lc = srv.metrics()["lifecycle"]
    assert lc["promotions"] == 1 and lc["shadow_batches"] >= 1
    srv.close()
