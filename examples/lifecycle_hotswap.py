"""Zero-downtime model update: registry -> delta reprogramming -> shadow ->
promote, on a live serving stream.

    PYTHONPATH=src python examples/lifecycle_hotswap.py [--dataset cancer]

The production event this walks through: a model drifts, gets retrained, and
the new version must reach the chip without dropping a request.

1. v1 and v2 (retrained on perturbed data) are published to a
   ``ModelRegistry`` — content-hashed, lineage-tracked, round-trip exact.
2. The ``LifecycleManager`` plans the reprogramming pass at write-pulse
   resolution: the delta touches only the cells whose state changed, and the
   modelled write energy / program time / endurance consumption are printed
   against the naive full erase-then-program pass.
3. ``stage()`` loads v2 into the server's shadow slot; a fraction of live
   traffic is mirrored through it and compared prediction-for-prediction.
4. ``promote()`` gates on shadow disagreement and the candidate's own golden
   canary, then atomically swaps v2 live — in-flight batches finish on v1,
   every future resolves.
"""
import argparse

import numpy as np

import repro
from repro.dt import DATASETS, load_split
from repro.serve import ServeConfig, TCAMServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="cancer")
    ap.add_argument("--s", type=int, default=128)
    ap.add_argument("--requests", type=int, default=512)
    ap.add_argument("--registry", default="artifacts/example_registry")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    spec = DATASETS[args.dataset]
    Xtr, ytr, Xte, yte = load_split(args.dataset)
    rng = np.random.default_rng(args.seed)

    # v1 on the clean split, v2 retrained after simulated drift
    v1 = repro.DT2CAM(s=args.s, max_depth=spec.max_depth).fit(Xtr, ytr)
    noise = rng.normal(0, 1, Xtr.shape) * 0.1 * Xtr.std(0, keepdims=True)
    v2 = repro.DT2CAM(s=args.s, max_depth=spec.max_depth).fit(
        Xtr + noise, ytr
    )

    reg = repro.ModelRegistry(args.registry)
    r1 = reg.publish(v1.compiled, args.dataset, metadata={"gen": 1})
    r2 = reg.publish(v2.compiled, args.dataset,
                     parents=[r1.version_id], metadata={"gen": 2})
    print(f"registry: {r1.version_id} -> {r2.version_id} "
          f"({len(reg)} versions)")

    cfg = ServeConfig(engine="ref", max_batch=64, max_delay_s=0.001)
    with TCAMServer(v1.compiled, config=cfg) as srv:
        mgr = repro.LifecycleManager(reg, srv, live_version=r1.version_id)

        # serve the first half of the stream on v1
        idx = rng.integers(0, len(Xte), size=args.requests)
        half = args.requests // 2
        futs = srv.submit_many(Xte[idx[:half]])

        # stage v2: delta-plan the reprogramming, mirror half of the traffic
        plan = mgr.stage(r2.version_id, mirror_fraction=0.5)
        figs = plan.figures()
        full = repro.plan_full(v1.compiled.layout.cells,
                               v2.compiled.layout.cells).figures()
        print(f"delta reprogram: {plan.n_cells_written} cells, "
              f"{figs['pulses']} pulses, {figs['energy_j'] * 1e9:.2f} nJ "
              f"(full pass: {full['pulses']} pulses, "
              f"{full['energy_j'] * 1e9:.2f} nJ)")

        # second half of the stream runs with the shadow mirror active
        futs += srv.submit_many(Xte[idx[half:]])
        srv.drain(timeout=120.0)

        report = mgr.promote(min_shadow_batches=1, max_disagreement=1.0)
        print(f"promotion: {report.reason} "
              f"(mirrored {report.shadow_requests} requests, "
              f"disagreement {report.disagreement_rate:.3f}, "
              f"canary {report.canary_accuracy:.3f})")

        dropped = sum(1 for f in futs if not f.done() or f.exception())
        served = np.array([r.prediction
                           for r in srv.serve(Xte[: min(256, len(Xte))])])
        ref = repro.simulate(
            v2.compiled.layout,
            repro.encode_inputs(v2.compiled.lut, Xte[: len(served)]),
        ).predictions
        print(f"dropped/errored across the swap: {dropped}")
        print(f"promoted model bit-exact vs v2 sim ref: "
              f"{bool(np.array_equal(served, ref))}")
        print(f"wear ledger: {mgr.wear.snapshot()}")
        print(f"live version: {mgr.live_version}")


if __name__ == "__main__":
    main()
