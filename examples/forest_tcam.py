"""Forests on TCAM banks: compile a bagged ensemble to one bank per tree,
then run it sharded — every same-shape group of banks evaluates as ONE
batched kernel invocation, groups pipelined, votes aggregated.

    PYTHONPATH=src python examples/forest_tcam.py

Shows the blessed top-level API (``import repro``): ``train_forest`` ->
``compile_forest`` -> ``forest_infer_ref`` (numpy oracle) and
``ForestExecutor`` (banked jax path), plus multi-bank serving through the
same ``TCAMServer`` that serves single trees.
"""
import numpy as np

import repro
from repro.dt import load_split


def main():
    Xtr, ytr, Xte, yte = load_split("cancer")

    # one CART tree per TCAM bank, bagged
    trees = repro.train_forest(Xtr, ytr, n_trees=8, max_depth=8, seed=0)
    forest = repro.compile_forest(trees, s=128)
    print(f"forest: {forest.n_banks} banks, "
          f"{sum(l.n_rows for l in forest.layouts)} rules total")

    # numpy oracle: per-bank functional sim + majority vote
    ref = repro.forest_infer_ref(forest, Xte)
    print(f"ref accuracy       : {ref.accuracy(yte):.4f}")
    agg = ref.figures["aggregate"]
    print(f"modelled aggregate : {agg['decs_pipe'] / 1e6:.0f} M dec/s over "
          f"{agg['n_banks']} pipelined banks "
          f"({agg['ensemble_decs_pipe'] / 1e6:.0f} M ensemble dec/s)")

    # banked jax execution: same survivors, same votes, bit-exact
    ex = repro.ForestExecutor(forest, engine="banked")
    res = ex.infer(Xte)
    assert (res.predictions == ref.predictions).all()
    print(f"banked engine      : parity with ref "
          f"({ex.plan.n_groups} execution group(s))")

    # serving: TCAMServer detects the forest and shards the batch path
    with repro.TCAMServer(forest) as server:
        server.warmup()
        results = server.serve(Xte[:64])
        preds = np.array([r.prediction for r in results])
    assert (preds == ref.predictions[:64]).all()
    print("served 64 requests : parity with ref")


if __name__ == "__main__":
    main()
