"""Batched DT-inference serving on the TCAM kernels (the paper's kind of
deployment: a stream of classification requests answered by one massively
parallel ternary match).

    PYTHONPATH=src python examples/serve_tcam.py [--dataset covid] [--s 64]

The serving path runs the jit'd Pallas-backed ``tcam_infer`` (bit-packed
engine when legal), batches incoming requests, and reports accuracy, energy
and modelled hardware throughput per batch — numbers consistent with
``core.simulate`` bit-for-bit.
"""
import argparse
import time

import numpy as np

from repro.core import compile_tree, train_tree
from repro.core.encode import encode_inputs
from repro.core.energy import DEFAULT_HW, f_max
from repro.dt import DATASETS, load_split
from repro.kernels import tcam_infer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="covid")
    ap.add_argument("--s", type=int, default=64)
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--batches", type=int, default=8)
    args = ap.parse_args()

    spec = DATASETS[args.dataset]
    Xtr, ytr, Xte, yte = load_split(args.dataset)
    tree = train_tree(Xtr, ytr, max_depth=spec.max_depth,
                      max_leaves=spec.max_leaves)
    c = compile_tree(tree, args.s)
    lay = c.layout
    print(f"{args.dataset}: LUT {c.lut.n_rows}x{c.lut.width}, "
          f"{lay.n_rwd}x{lay.n_cwd} tiles of {args.s}x{args.s}")

    served = correct = 0
    energy = 0.0
    t0 = time.perf_counter()
    for i in range(args.batches):
        lo = (i * args.batch) % max(1, len(Xte) - args.batch)
        req, lab = Xte[lo:lo + args.batch], yte[lo:lo + args.batch]
        xb = encode_inputs(c.lut, req)
        preds, surv, nsurv, evals, e = tcam_infer(lay, xb)
        served += len(req)
        correct += int((np.asarray(preds) == lab).sum())
        energy += float(np.asarray(e).sum())
    dt = time.perf_counter() - t0

    hw_thpt = f_max(args.s) / lay.n_cwd
    print(f"served {served} requests in {dt:.2f}s "
          f"(functional sim on CPU)")
    print(f"accuracy: {correct / served:.4f}")
    print(f"modelled ReCAM: {energy / served * 1e9:.4f} nJ/dec, "
          f"{hw_thpt / 1e6:.1f} M dec/s sequential, "
          f"{f_max(args.s) / DEFAULT_HW.pipeline_ii_cycles / 1e6:.0f} "
          f"M dec/s pipelined")


if __name__ == "__main__":
    main()
