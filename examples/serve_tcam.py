"""Batched DT-inference serving on the TCAM kernels (the paper's kind of
deployment: a stream of classification requests answered by one massively
parallel ternary match).

    PYTHONPATH=src python examples/serve_tcam.py [--dataset covid] [--s 64]

Requests are pushed one at a time into a ``repro.serve.TCAMServer`` — the
production engine: adaptive batch formation (flush on max-batch or deadline),
padding-bucket batching with a warm jit compile cache, automatic engine
selection (bit-packed kernel when legal, MXU bitplane kernel otherwise) and a
metrics layer.  The printout reports accuracy, serving latency percentiles,
and the modelled ReCAM energy/throughput — consistent bit-for-bit with
``core.simulate`` / ``DT2CAM.infer``.
"""
import argparse
import time

import numpy as np

from repro.core import compile_tree, train_tree
from repro.dt import DATASETS, load_split
from repro.serve import ServeConfig, TCAMServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="covid")
    ap.add_argument("--s", type=int, default=64)
    ap.add_argument("--requests", type=int, default=1024)
    ap.add_argument("--max-batch", type=int, default=128)
    ap.add_argument("--max-delay-ms", type=float, default=2.0)
    ap.add_argument("--engine", default="auto",
                    choices=["auto", "mxu", "packed", "ref"])
    args = ap.parse_args()

    spec = DATASETS[args.dataset]
    Xtr, ytr, Xte, yte = load_split(args.dataset)
    tree = train_tree(Xtr, ytr, max_depth=spec.max_depth,
                      max_leaves=spec.max_leaves)
    c = compile_tree(tree, args.s)
    lay = c.layout
    print(f"{args.dataset}: LUT {c.lut.n_rows}x{c.lut.width}, "
          f"{lay.n_rwd}x{lay.n_cwd} tiles of {args.s}x{args.s}")

    cfg = ServeConfig(max_batch=args.max_batch,
                      max_delay_s=args.max_delay_ms / 1e3,
                      engine=args.engine)
    idx = np.arange(args.requests) % len(Xte)
    t0 = time.perf_counter()
    with TCAMServer(c, config=cfg) as server:
        print(f"engine: {server.engine}, buckets: {server.policy.buckets}, "
              f"warmed {server.warmup()} compiles")
        results = server.serve(Xte[idx])
        stats = server.metrics()
    dt = time.perf_counter() - t0

    preds = np.array([r.prediction for r in results])
    acc = float((preds == yte[idx]).mean())
    print(f"served {len(results)} requests in {dt:.2f}s "
          f"({len(results) / dt:.0f} req/s functional sim on "
          f"{'CPU' if cfg.interpret is not False else 'TPU'}) "
          f"in {stats['batches']} batches "
          f"(fill {stats['mean_batch_fill']:.2f}, "
          f"jit compiles {stats['jit_cache']['misses']})")
    print(f"accuracy: {acc:.4f}")
    print(f"queue   p50/p99: {stats['queue_latency']['p50_ms']:.2f}/"
          f"{stats['queue_latency']['p99_ms']:.2f} ms")
    print(f"compute p50/p99: {stats['compute_latency']['p50_ms']:.2f}/"
          f"{stats['compute_latency']['p99_ms']:.2f} ms")
    print(f"modelled ReCAM: {stats['modelled_nj_per_dec']:.4f} nJ/dec, "
          f"{stats['modelled_mdecs_seq']:.1f} M dec/s sequential, "
          f"{stats['modelled_mdecs_pipe']:.0f} M dec/s pipelined")


if __name__ == "__main__":
    main()
