"""Quickstart: the paper's Fig 2 pipeline on the real (embedded) Iris data.

    PYTHONPATH=src python examples/quickstart.py

Trains a CART tree, compiles it through the DT-HW pipeline (parse -> column
reduction -> ternary adaptive encoding), synthesizes S x S ReCAM tiles, and
runs the functional simulation — verifying the paper's central claim that
the TCAM-simulated accuracy equals the Python golden-DT accuracy.
"""
import numpy as np

from repro.core import DT2CAM, NonIdealSpec
from repro.dt import load_split


def main():
    Xtr, ytr, Xte, yte = load_split("iris")
    model = DT2CAM(s=16, max_depth=5).fit(Xtr, ytr)

    c = model.compiled
    print(f"tree: {c.tree.n_leaves} leaves, depth {c.tree.depth()}")
    print(f"LUT:  {c.lut.n_rows} x {c.lut.width} ternary cells "
          f"(paper Table V: 9 x 12)")
    print(f"tiles: {c.layout.n_rwd} x {c.layout.n_cwd} of "
          f"{c.layout.s} x {c.layout.s}")

    res = model.infer(Xte)
    golden = model.golden_accuracy(Xte, yte)
    print(f"golden DT accuracy : {golden:.4f}")
    print(f"TCAM sim accuracy  : {res.accuracy(yte):.4f}  "
          f"(must match exactly)")
    assert res.accuracy(yte) == golden

    print(f"energy  : {res.mean_energy * 1e12:.3f} pJ/decision")
    print(f"latency : {res.latency_s * 1e9:.3f} ns/decision")
    print(f"thruput : {res.throughput_seq / 1e6:.1f} M dec/s sequential, "
          f"{res.throughput_pipe / 1e6:.1f} M dec/s pipelined")

    # robustness: stuck-at faults
    faulty = model.infer(Xte, nonideal=NonIdealSpec(p_sa0=0.01, p_sa1=0.01))
    print(f"accuracy w/ 1% SAF : {faulty.accuracy(yte):.4f}")


if __name__ == "__main__":
    main()
