"""Beyond-paper integration (DESIGN.md §4): an MoE layer whose routing
decisions come from a decision tree compiled to a TCAM LUT by the paper's
DT-HW compiler and evaluated in-graph as a ternary match.

    PYTHONPATH=src python examples/tcam_moe_router.py

Pipeline: distil a trained softmax router into a CART tree (teacher top-1
labels on hidden states) -> compile_router (parse / reduce / encode) ->
route via the bitplane match inside ``moe_ffn(router="tcam_dt")``.
"""
import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import predict, train_tree
from repro.models.config import ModelConfig
from repro.models.moe import moe_ffn
from repro.models.params import init_params
from repro.models.tcam_router import compile_router, route_tcam


def main():
    cfg = ModelConfig(
        name="moe_demo", family="moe", n_layers=1, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=256, vocab_size=1024,
        pattern=("attn+moe",), n_experts=8, experts_per_token=2,
        moe_d_ff=256, capacity_factor=4.0)
    p = jax.tree.map(
        lambda a: a[0],
        init_params(cfg, jax.random.PRNGKey(0))["blocks"]["attn+moe"])

    rng = np.random.default_rng(0)
    # "hidden states" + teacher softmax router top-1 labels
    H = rng.standard_normal((4096, cfg.d_model)).astype(np.float32)
    logits = H @ np.asarray(p["w_router"], np.float32)
    teacher = logits.argmax(-1).astype(np.int64)

    tree = train_tree(H, teacher, max_depth=10, max_leaves=256)
    agree_tree = float((predict(tree, H) == teacher).mean())
    bits = compile_router(tree)
    n_rows, n_bits = bits["is0"].shape
    print(f"distilled router tree: {tree.n_leaves} leaves "
          f"-> TCAM LUT {n_rows} x {n_bits}")
    print(f"tree vs teacher top-1 agreement: {agree_tree:.3f}")

    got = np.asarray(route_tcam(jnp.asarray(H), bits))
    assert (got == predict(tree, H)).all(), "TCAM match == tree (bijective)"
    print("in-graph TCAM routing == tree inference: OK")

    cfg_tcam = dataclasses.replace(cfg, router="tcam_dt")
    x = jnp.asarray(rng.standard_normal((2, 32, cfg.d_model)), jnp.float32)
    y_soft = moe_ffn(x, p, cfg)
    y_tcam = moe_ffn(x, p, cfg_tcam, router_bits=bits)
    print(f"moe_ffn(softmax) vs moe_ffn(tcam_dt): "
          f"output shapes {y_soft.shape} == {y_tcam.shape}, "
          f"mean |Δ| = {float(jnp.abs(y_soft - y_tcam).mean()):.4f} "
          f"(top-1 distilled vs top-2 soft: differences expected)")


if __name__ == "__main__":
    main()
