"""End-to-end LM training driver (deliverable b): trains a ~100M-param dense
model for a few hundred steps on the planted-structure pipeline with the
full production stack — sharding rules, AdamW, checkpointing, and the
fault-tolerant loop.

    PYTHONPATH=src python examples/train_lm.py --steps 300

On this CPU container it uses a single-device mesh; the identical step
function lowers onto the 16x16 / 2x16x16 production meshes (see
``repro.launch.dryrun``).
"""
import argparse
import dataclasses

import jax

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import TokenPipeline
from repro.launch.mesh import mesh_for_devices
from repro.optim import AdamWConfig
from repro.runtime import FaultTolerantLoop, StragglerMonitor
from repro.sharding import make_rules
from repro.train import build_train_step, init_train_state
from repro.models import param_count


def hundred_m_config():
    """~100M params: a scaled-down olmo-family config."""
    base = get_config("olmo_1b")
    return dataclasses.replace(
        base, name="olmo_100m", n_layers=8, d_model=512, n_heads=8,
        n_kv_heads=8, head_dim=64, d_ff=2048, vocab_size=50304)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    cfg = hundred_m_config()
    rules = make_rules(mesh_for_devices())
    opt = AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps,
                      weight_decay=0.01)
    state = init_train_state(cfg, jax.random.PRNGKey(0), opt_cfg=opt)
    print(f"model: {cfg.name}, {param_count(state.params) / 1e6:.1f}M params")

    step_fn = jax.jit(build_train_step(cfg, rules, opt))
    pipe = TokenPipeline(cfg, args.batch, args.seq, seed=0)
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)
    loop = FaultTolerantLoop(
        step_fn,
        lambda s: {k: jax.numpy.asarray(v) for k, v in pipe.batch_at(s).items()},
        ckpt, ckpt_every=100,
        straggler=StragglerMonitor(),
        install_sigterm=True,
    )

    # auto-resume from the latest checkpoint (restart-safe driver)
    restored = ckpt.restore(state)
    start = 0
    if restored is not None:
        state, start = restored
        print(f"resumed from checkpoint at step {start}")

    state, end, hist = loop.run(state, start, args.steps - start,
                                log_every=25)
    first = sum(h["loss"] for h in hist[:10]) / max(len(hist[:10]), 1)
    last = sum(h["loss"] for h in hist[-10:]) / max(len(hist[-10:]), 1)
    print(f"loss {first:.3f} -> {last:.3f} over {len(hist)} steps "
          f"({loop.straggler.stragglers} straggler steps)")
    ckpt.save(end, state)


if __name__ == "__main__":
    main()
