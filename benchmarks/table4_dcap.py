"""Paper Table IV: dynamic-range limit -> max cells/row -> chosen S."""
from repro.core import choose_tile_size, dynamic_range, max_cells_per_row

from .common import emit

PAPER = {0.2: (154, 128), 0.3: (86, 64), 0.4: (53, 32), 0.5: (33, 32),
         0.6: (21, 16)}


def run() -> list[dict]:
    rows = []
    for d_limit, (p_cells, p_s) in PAPER.items():
        cells = max_cells_per_row(d_limit)
        s = choose_tile_size(d_limit)
        rows.append({
            "d_limit_V": d_limit,
            "max_cells_per_row": cells,
            "paper_max_cells": p_cells,
            "chosen_S": s,
            "paper_S": p_s,
            "match": cells == p_cells and s == p_s,
            "d_at_S": round(dynamic_range(s), 4),
        })
    return rows


def main():
    emit(run(), "Table IV — D_cap limit vs TCAM row size (Eqn 6)")


if __name__ == "__main__":
    main()
