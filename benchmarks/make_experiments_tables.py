"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from the
dry-run artifacts.  Usage: python -m benchmarks.make_experiments_tables"""
import glob
import json
import os

from .roofline import ART, cell_rows


def dryrun_table() -> str:
    out = ["| arch | shape | mesh | GiB/dev | fits 16GiB | compile s | "
           "top collectives |", "|---|---|---|---|---|---|---|"]
    for path in sorted(glob.glob(os.path.join(ART, "*.json"))):
        r = json.load(open(path))
        gib = (r["memory"]["argument_bytes"]
               + r["memory"]["temp_bytes"]) / 2**30
        mesh = "x".join(map(str, r["mesh"]))
        coll = sorted(r["collectives"].items(), key=lambda kv: -kv[1])[:2]
        coll_s = "; ".join(f"{k} {v/2**30:.2f}GiB" for k, v in coll) or "-"
        out.append(
            f"| {r['arch']} | {r['shape']} | {mesh} | {gib:.2f} | "
            f"{'yes' if gib <= 16.0 else 'NO'} | {r['t_compile_s']} | "
            f"{coll_s} |")
    return "\n".join(out)


def roofline_table(mesh="singlepod") -> str:
    rows = cell_rows(mesh)
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| model TF/dev | useful ratio | roofline frac |")
    out = [hdr, "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"{r['dominant']} | {r['model_tflops_dev']:.1f} | "
            f"{r['useful_ratio']:.3f} | {r['roofline_frac']:.3f} |")
    return "\n".join(out)


def main():
    print("## Dry-run table\n")
    print(dryrun_table())
    print("\n## Roofline (single-pod)\n")
    print(roofline_table("singlepod"))


if __name__ == "__main__":
    main()
