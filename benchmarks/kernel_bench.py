"""TCAM-kernel benchmark: engines (numpy oracle / jnp ref / MXU formulation /
bit-packed) on the Covid LUT and the traffic-scale LUT.

Wall-clock here is CPU (XLA-compiled jnp for ref; the Pallas kernels run
interpret=True and are validated for correctness, not speed).  The TPU story
is the **bytes model**: per input batch the match must stream the LUT planes
from HBM, so

    MXU engine    ~ 2 planes x f32  = 8 B/cell
    packed engine ~ 2 words / 32    = 0.25 B/cell   (32x fewer bytes)

which moves the kernel's roofline from memory-bound toward compute-bound —
the paper-representative §Perf hillclimb in EXPERIMENTS.md.
"""
import time

import numpy as np

import jax

from repro.core import bitplanes, encode_inputs, simulate
from repro.kernels import tcam_match_ref, tcam_match_packed_ref, pack_bits

from .common import compiled, emit


def _bench(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        r = fn(*args)
        jax.block_until_ready(r)
    return (time.perf_counter() - t0) / reps


def run() -> list[dict]:
    import jax.numpy as jnp
    rows = []
    for name, s, batch in (("covid", 64, 512), ("covid", 128, 512)):
        c, (Xtr, ytr, Xte, yte) = compiled(name, s)
        from repro.core import synthesize
        lay = synthesize(c.lut, s)
        xb = encode_inputs(c.lut, Xte[:batch])
        xp = lay.pad_inputs(xb)
        is0, is1 = bitplanes(lay.cells)
        r, w = lay.cells.shape

        t_np = _bench(lambda: simulate(lay, xb), reps=2)
        j_ref = jax.jit(lambda x, a, b: tcam_match_ref(x, a, b, s))
        t_ref = _bench(j_ref, jnp.asarray(xp, jnp.float32),
                       jnp.asarray(is0), jnp.asarray(is1))
        xq = pack_bits(jnp.asarray(xp))
        val = pack_bits(jnp.asarray(is1))
        care = pack_bits(jnp.asarray(is0 | is1))
        j_pk = jax.jit(lambda x, v, cc: tcam_match_packed_ref(x, v, cc, s))
        t_pk = _bench(j_pk, xq, val, care)

        cells = r * w
        rows.append({
            "workload": f"{name}_S{s}", "rows": r, "width": w,
            "batch": batch,
            "numpy_sim_ms": round(t_np * 1e3, 2),
            "jnp_mxu_ms": round(t_ref * 1e3, 2),
            "jnp_packed_ms": round(t_pk * 1e3, 2),
            "speedup_packed_vs_numpy": round(t_np / t_pk, 1),
            "bytes_per_cell_mxu": 8.0,
            "bytes_per_cell_packed": 0.25,
            "tpu_mem_term_mxu_us": round(cells * 8 / 819e9 * 1e6, 2),
            "tpu_mem_term_packed_us": round(cells * 0.25 / 819e9 * 1e6, 3),
        })
    return rows


def main():
    emit(run(), "Kernel engines — functional throughput + TPU bytes model")


if __name__ == "__main__":
    main()
