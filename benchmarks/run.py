"""Benchmark harness entry point: one benchmark per paper table/figure plus
the kernel-engine table.  ``python -m benchmarks.run [--fast]``."""
import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="skip the slow Credit / traffic-scale workloads")
    args = ap.parse_args()

    from . import (fig6_energy_throughput, fig7_nonidealities, kernel_bench,
                   table4_dcap, table5_tiles, table6_comparison)
    from .common import emit

    t0 = time.time()
    table4_dcap.main()
    if args.fast:
        emit(fig6_energy_throughput.run(
            ["iris", "cancer", "haberman", "car"]), "Fig 6 (fast subset)")
        emit(fig7_nonidealities.run(("cancer",), trials=2),
             "Fig 7 (fast subset)")
    else:
        table5_tiles.main()
        fig6_energy_throughput.main()
        fig7_nonidealities.main()
        table6_comparison.main()
        kernel_bench.main()
    print(f"# total {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
