"""Fault-injection chaos harness for the reliability layer.

Two experiment families, emitted as one JSON report (CI artifact):

1. **Fault sweep** — for each dataset and stuck-at probability p (=p_sa0
   =p_sa1), sample faulty chips and measure:
     * BIST coverage against the analytic behavior-change ground truth;
     * test accuracy of the ideal chip, the faulty chip, and the chip after
       spare-row repair (the headline claim: repair recovers to within ~1%
       of ideal at p = 2%);
     * k-chip majority voting (``ReplicatedServer``) accuracy and the
       observed disagreement rate.
2. **Serving chaos** — a live ``TCAMServer`` under injected *compute*
   faults (via ``fault_injection_hook``), a bounded queue, and per-request
   deadlines.  The invariant under test: the server never hangs — every
   submitted Future resolves with a result or a typed serving error, and
   the shed / deadline / retry / compute-failure counters surface in
   ``metrics()``.

Run:  PYTHONPATH=src python -m benchmarks.chaos_harness \
          --datasets iris,cancer,car --p-grid 0.005,0.02 --trials 3
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import numpy as np

from benchmarks.common import ART, fitted_tree
from repro.core import compile_tree
from repro.core import (NonIdealSpec, apply_saf_mask, encode_inputs,
                        sample_saf, simulate)
from repro.reliability import (
    ReplicatedServer,
    behavior_changed_rows,
    repair_layout,
    row_utilization,
    run_bist,
)
from repro.serve import (
    ComputeFailed,
    DeadlineExceeded,
    Rejected,
    ServeConfig,
    TCAMServer,
)


def _acc(layout, lut, X, y) -> float:
    return float((simulate(layout, encode_inputs(lut, X)).predictions == y).mean())


# -- experiment 1: stuck-at fault sweep (BIST coverage + repair recovery) ----
def fault_sweep(datasets, p_grid, trials, k, seed) -> list[dict]:
    rows = []
    for name in datasets:
        tree, (Xtr, ytr, Xte, yte) = fitted_tree(name)
        n = compile_tree(tree).layout.n_rows
        c = compile_tree(tree, spare_rows=2 * n)
        lay, lut = c.layout, c.lut
        used = 1 + lay.width
        acc_ideal = _acc(lay, lut, Xte, yte)
        prio = row_utilization(lay, encode_inputs(lut, Xtr))
        for p in p_grid:
            spec = NonIdealSpec(p_sa0=p, p_sa1=p)
            for trial in range(trials):
                rng = np.random.default_rng(seed + 1000 * trial)
                mask = sample_saf(lay.cells.shape, p, p, rng)
                faulty = apply_saf_mask(lay.cells, mask)
                flay = dataclasses.replace(lay, cells=faulty)

                bist = run_bist(faulty, lay.cells, used=used,
                                n_rows=lay.cells.shape[0])
                changed = behavior_changed_rows(lay.cells, faulty, used)
                rlay, _, rr = repair_layout(
                    flay, lay.cells, mask, bist.defective_rows, priority=prio
                )

                # k-chip majority voting on an eval slice (ref engine keeps
                # the harness fast; the voting logic is engine-agnostic)
                n_eval = min(64, len(yte))
                with ReplicatedServer(
                    c, k=k, nonideal=spec,
                    rng=np.random.default_rng(seed + 1000 * trial),
                    config=ServeConfig(engine="ref", background=False,
                                       max_batch=n_eval),
                ) as rs:
                    voted = rs.serve(Xte[:n_eval])
                    acc_voted = float(np.mean(
                        [v.prediction for v in voted] == yte[:n_eval]
                    ))
                    vote_m = rs.metrics()

                rows.append({
                    "dataset": name, "p": p, "trial": trial,
                    "defective_rows": bist.n_defective,
                    "changed_rows": int(changed.sum()),
                    "bist_coverage": bist.coverage(changed),
                    "probes_run": bist.probes_run,
                    "acc_ideal": acc_ideal,
                    "acc_faulty": _acc(flay, lut, Xte, yte),
                    "acc_repaired": _acc(rlay, lut, Xte, yte),
                    "repair": rr.summary(),
                    "k": k,
                    "acc_voted": acc_voted,
                    "disagreement_rate": vote_m["disagreement_rate"],
                })
                r = rows[-1]
                print(f"{name} p={p} t{trial}: cov={r['bist_coverage']:.3f} "
                      f"acc i/f/r/v={acc_ideal:.3f}/{r['acc_faulty']:.3f}/"
                      f"{r['acc_repaired']:.3f}/{acc_voted:.3f} "
                      f"repaired={rr.rows_repaired} "
                      f"unrep={len(rr.unrepaired)}")
    return rows


# -- experiment 2: serving chaos (compute faults, shedding, deadlines) -------
def serving_chaos(dataset, seed) -> dict:
    import threading

    tree, (Xtr, ytr, Xte, yte) = fitted_tree(dataset)
    c = compile_tree(tree)
    X = np.tile(np.asarray(Xte), (max(1, 64 // len(Xte)) + 1, 1))

    # 2a: transient compute faults absorbed by the retry budget
    fail_next = [2]

    def flaky(_X):
        if fail_next[0] > 0:
            fail_next[0] -= 1
            raise RuntimeError("injected transient device fault")

    cfg = ServeConfig(engine="ref", max_batch=16, max_delay_s=0.001,
                      max_retries=3, retry_backoff_s=0.001)
    with TCAMServer(c, config=cfg, rng=np.random.default_rng(seed)) as s:
        s.fault_injection_hook = flaky
        res = s.serve(X[:32])
        retried = s.metrics()["reliability"]
        ok_after_retry = len(res) == 32 and retried["retries"] >= 2

    # 2b: a stalled-then-faulty device, a tiny bounded queue, and short
    # per-request deadlines: every future must still resolve (result or
    # typed error) and drain must not hang.  The first batch stalls the
    # worker (gate) so the queue genuinely fills and queued requests expire.
    gate = threading.Event()
    calls = [0]

    def stall_then_fault(_X):
        calls[0] += 1
        if calls[0] <= 2:          # first batch + its one retry
            gate.wait(30.0)
            raise RuntimeError("injected persistent device fault")

    cfg = ServeConfig(engine="ref", max_batch=4, min_bucket=4,
                      max_delay_s=0.001,
                      max_queue=8, request_timeout_s=0.05,
                      max_retries=1, retry_backoff_s=0.001)
    counts = {"ok": 0, "rejected": 0, "deadline": 0, "compute_failed": 0}
    with TCAMServer(c, config=cfg, rng=np.random.default_rng(seed)) as s:
        s.fault_injection_hook = stall_then_fault
        futs = [s.submit(x) for x in X[:40]]   # floods the bounded queue
        time.sleep(0.2)                        # queued requests expire
        gate.set()                             # stalled batch fails + retries
        s.drain(timeout=60.0)
        futs += [s.submit(x) for x in X[:8]]   # device recovered
        s.drain(timeout=60.0)
        for f in futs:
            assert f.done(), "unresolved future: the server hung"
            e = f.exception()
            if e is None:
                counts["ok"] += 1
            elif isinstance(e, Rejected):
                counts["rejected"] += 1
            elif isinstance(e, DeadlineExceeded):
                counts["deadline"] += 1
            elif isinstance(e, ComputeFailed):
                counts["compute_failed"] += 1
        chaos_metrics = s.metrics()["reliability"]

    report = {
        "dataset": dataset,
        "transient": {"served": ok_after_retry, "metrics": retried},
        "persistent": {"outcomes": counts, "metrics": chaos_metrics,
                       "all_futures_resolved": True,
                       "n_futures": len(futs)},
    }
    print(f"chaos[{dataset}]: transient served={ok_after_retry} "
          f"retries={retried['retries']} | persistent outcomes={counts}")
    return report


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--datasets", default="iris,cancer,car")
    ap.add_argument("--p-grid", default="0.005,0.02")
    ap.add_argument("--trials", type=int, default=3)
    ap.add_argument("--k", type=int, default=3)
    ap.add_argument("--seed", type=int, default=100)
    ap.add_argument("--out", default=os.path.join(ART, "chaos_harness.json"))
    args = ap.parse_args()

    datasets = [d for d in args.datasets.split(",") if d]
    p_grid = [float(p) for p in args.p_grid.split(",") if p]

    t0 = time.time()
    # meta carries only seed-determined fields: same flags + same seed ->
    # byte-identical artifact JSON (wall time goes to stdout, not the file)
    report = {
        "meta": {"datasets": datasets, "p_grid": p_grid,
                 "trials": args.trials, "k": args.k, "seed": args.seed},
        "fault_sweep": fault_sweep(datasets, p_grid, args.trials,
                                   args.k, args.seed),
        "serving_chaos": serving_chaos(datasets[0], args.seed),
    }

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {args.out} ({time.time() - t0:.1f}s)")


if __name__ == "__main__":
    main()
