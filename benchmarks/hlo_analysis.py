"""Loop-aware HLO cost analyzer.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body ONCE, so any
scanned computation (layer stacks, microbatch accumulation, flash-attention
chunks, MoE groups) is undercounted by its trip count.  This module parses
the *partitioned, post-optimization* HLO text (per-device shapes) and
computes — with while-loop trip multipliers applied recursively:

  * dot FLOPs        2 x prod(output dims) x prod(lhs contracting dims),
                     operand shapes resolved via a per-computation symbol
                     table (params + instruction defs);
  * collective bytes output bytes of all-gather / all-reduce /
                     reduce-scatter / all-to-all / collective-permute
                     (async -start/-done pairs counted once), per type;
  * HBM bytes        post-fusion HLO executes one kernel per top-level
                     instruction, so Σ(output bytes + operand bytes) over
                     instructions (skipping free ops: parameter/constant/
                     tuple/GTE/bitcast) approximates HBM traffic.

Trip counts come from the loop-condition computation: the largest integer
constant compared against the induction variable (standard XLA scan
lowering).  Non-dot FLOPs (elementwise, reductions) are excluded from the
FLOPs term — dot terms dominate at these sizes (documented in
EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import dataclasses
import gzip
import re
from functools import lru_cache

__all__ = ["analyze_hlo", "analyze_file", "HloCost"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE = re.compile(r"\b(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64)"
                    r"\[([0-9,]*)\]")
_DEF = re.compile(r"^(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_OPNAME = re.compile(r"^\(?(?:\(|\s)*(?:[\w\[\],{}/*\s]*?)?\s*"
                     r"([a-z][a-z0-9\-]*)\(")
_OPERANDS = re.compile(r"%([\w.\-]+)")
_COLLECTIVE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_CONST_INT = re.compile(r"constant\((\d+)\)")
_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "after-all", "iota"}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape_dims(text: str) -> list[int] | None:
    m = _SHAPE.search(text)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class HloCost:
    dot_flops: float = 0.0
    collective_bytes: float = 0.0
    hbm_bytes: float = 0.0
    coll_by_type: dict = dataclasses.field(default_factory=dict)

    def scaled(self, k: float) -> "HloCost":
        return HloCost(self.dot_flops * k, self.collective_bytes * k,
                       self.hbm_bytes * k,
                       {t: b * k for t, b in self.coll_by_type.items()})

    def add(self, other: "HloCost") -> None:
        self.dot_flops += other.dot_flops
        self.collective_bytes += other.collective_bytes
        self.hbm_bytes += other.hbm_bytes
        for t, b in other.coll_by_type.items():
            self.coll_by_type[t] = self.coll_by_type.get(t, 0.0) + b


@dataclasses.dataclass
class _Comp:
    name: str
    lines: list
    symbols: dict          # %name -> shape text (dtype[dims])
    entry: bool = False


def _parse(text: str) -> dict[str, _Comp]:
    comps: dict[str, _Comp] = {}
    cur: _Comp | None = None
    for raw in text.splitlines():
        line = raw.strip()
        if line.endswith("{") and ("->" in line or line.startswith("ENTRY")):
            header = line
            m = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)", header)
            if not m:
                continue
            cur = _Comp(m.group(2), [], {}, entry=bool(m.group(1)))
            comps[cur.name] = cur
            # parameters in header: name: TYPE[dims]
            for pm in re.finditer(r"([\w.\-]+):\s*((?:\w+\[[0-9,]*\]|\([^)]*\)))",
                                  header):
                cur.symbols[pm.group(1)] = pm.group(2)
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None or not line:
            continue
        cur.lines.append(line)
        dm = _DEF.match(line)
        if dm:
            rhs = dm.group(2)
            sm = _SHAPE.search(rhs.split("(", 1)[0]) or _SHAPE.search(rhs)
            if sm:
                cur.symbols[dm.group(1)] = sm.group(0)
    return comps


def _dot_flops(line: str, comp: _Comp) -> float:
    lhs_rhs = line.split(" dot(", 1)
    if len(lhs_rhs) != 2:
        return 0.0
    out_dims = _first_shape_dims(lhs_rhs[0])
    if out_dims is None:
        return 0.0
    out_n = 1
    for d in out_dims:
        out_n *= d
    ops = _OPERANDS.findall(lhs_rhs[1].split(")", 1)[0])
    cm = re.search(r"lhs_contracting_dims={([0-9,]*)}", line)
    contract = 1
    if cm and ops:
        lhs_shape = comp.symbols.get(ops[0])
        dims = _first_shape_dims(lhs_shape or "") or []
        for idx in cm.group(1).split(","):
            if idx and int(idx) < len(dims):
                contract *= dims[int(idx)]
    return 2.0 * out_n * contract


def _line_cost(line: str, comp: _Comp) -> HloCost:
    c = HloCost()
    dm = _DEF.match(line)
    if not dm:
        return c
    rhs = dm.group(2)
    # op name = token right before the first '(' after the output type
    after_type = rhs
    sm = _SHAPE.search(rhs)
    opm = re.search(r"\b([a-z][a-z0-9\-]*)\(", rhs)
    op = opm.group(1) if opm else ""
    out_bytes = _shape_bytes(rhs.split(op + "(", 1)[0]) if op else 0

    if op == "dot":
        c.dot_flops += _dot_flops(line, comp)

    mcol = _COLLECTIVE.search(line)
    if mcol and mcol.group(2) != "-done":
        ctype = mcol.group(1)
        c.collective_bytes += out_bytes
        c.coll_by_type[ctype] = c.coll_by_type.get(ctype, 0.0) + out_bytes

    if op and op not in _FREE_OPS and not op.endswith("-done"):
        # HBM traffic model: each post-fusion instruction writes its output
        # once; reads are NOT charged (they would be charged once per
        # consumer and overcount heavily).  This is a lower bound on reads
        # + exact on writes; converts/copies excluded (fused on TPU).
        if op not in ("convert", "copy", "while", "conditional",
                      "broadcast", "reshape", "transpose"):
            c.hbm_bytes += out_bytes
    return c


def _trip_count(cond: _Comp | None) -> int:
    if cond is None:
        return 1
    best = 1
    for line in cond.lines:
        for m in _CONST_INT.finditer(line):
            best = max(best, int(m.group(1)))
    return best


def analyze_hlo(text: str) -> HloCost:
    comps = _parse(text)
    entry = next((c.name for c in comps.values() if c.entry), None)
    if entry is None:
        referenced = set()
        for comp in comps.values():
            for line in comp.lines:
                for m in re.finditer(
                        r"(?:to_apply|body|condition|calls)=%?([\w.\-]+)",
                        line):
                    referenced.add(m.group(1))
        cands = [n for n in comps if n not in referenced]
        entry = cands[-1] if cands else next(iter(comps))

    memo: dict[str, HloCost] = {}

    def cost_of(name: str) -> HloCost:
        if name in memo:
            return memo[name]
        total = HloCost()
        memo[name] = total
        comp = comps.get(name)
        if comp is None:
            return total
        for line in comp.lines:
            total.add(_line_cost(line, comp))
            if " while(" in line or line.startswith("while("):
                mb = re.search(r"body=%?([\w.\-]+)", line)
                mc = re.search(r"condition=%?([\w.\-]+)", line)
                if mb:
                    trips = _trip_count(comps.get(mc.group(1)) if mc else None)
                    total.add(cost_of(mb.group(1)).scaled(trips))
            else:
                for m in re.finditer(
                        r"(?:to_apply|calls)=%?([\w.\-]+)", line):
                    if m.group(1) in comps and m.group(1) != name:
                        total.add(cost_of(m.group(1)))
        memo[name] = total
        return total

    return cost_of(entry)


@lru_cache(maxsize=None)
def analyze_file(path: str) -> HloCost:
    opener = gzip.open if path.endswith(".gz") else open
    with opener(path, "rt") as f:
        return analyze_hlo(f.read())
