"""Temporal-degradation campaign: accuracy vs. drift horizon, with and
without online scrubbing.

Two seed-matched chips per dataset (identical drift sample) age along the
same virtual-time checkpoints.  The *no-scrub* arm just keeps serving as
conductances drift and retention flips cells — accuracy collapses once
drifted resistances cross the read midpoint.  The *scrub* arm runs the
margin-policy maintenance pass (``TCAMServer.scrub_now``) at every
checkpoint, which refreshes weak rows through the SET/RESET write planner,
so its accuracy stays within the guardrail (<= 1% below fresh) while the
refresh energy and program pulses land in the wear ledger and the metrics
snapshot.  A final chaos section scrubs concurrently with a live request
stream and asserts every in-flight future resolves exactly once.

The artifact is fully seed-deterministic (virtual clock, no wall time):

    PYTHONPATH=src python -m benchmarks.degradation_bench [--seed 0]
"""
from __future__ import annotations

import argparse
import os
import threading

import numpy as np

from repro.core import DriftSpec, NonIdealSpec
from repro.serve import ServeConfig, TCAMServer

from .common import ART, add_seed_arg, compiled, emit, write_artifact

# Drift law parameters for the campaign: mild power-law conductance drift
# plus a finite retention time constant, so the no-scrub arm collapses
# inside the checkpoint horizon (flip threshold sqrt(r_hrs/r_lrs) ~ 22x).
DRIFT = DriftSpec(nu=0.05, nu_sigma=0.02, t0=1.0, retention_tau_s=2e6)
CHECKPOINTS = (1e5, 1e6, 3e6, 1e7, 3e7)   # cumulative virtual seconds
GUARDRAIL = 0.01                          # scrubbed accuracy vs fresh
COLLAPSE = 0.02                           # no-scrub must degrade at least this


def _server(c, seed: int, **cfg_kw) -> TCAMServer:
    kw = dict(engine="ref", background=False, max_batch=64)
    kw.update(cfg_kw)
    return TCAMServer(c, nonideal=NonIdealSpec(drift=DRIFT),
                      config=ServeConfig(**kw),
                      rng=np.random.default_rng(seed))


def _accuracy(server: TCAMServer, X, y) -> float:
    preds = np.array([r.prediction for r in server.serve(X)])
    return float((preds == y).mean())


def _margin_min(server: TCAMServer) -> float:
    return float(server.margins().margin.min())


def run_dataset(name: str, *, s: int, seed: int) -> tuple[dict, list[dict]]:
    c, (Xtr, ytr, Xte, yte) = compiled(name, s)
    # identical construction order => identical rng draws => both arms age
    # the exact same sampled chip
    plain = _server(c, seed)
    scrubbed = _server(c, seed)
    fresh = _accuracy(plain, Xte, yte)
    assert _accuracy(scrubbed, Xte, yte) == fresh, "arms diverged at t=0"

    timeline = []
    prev_t = 0.0
    for t in CHECKPOINTS:
        dt = t - prev_t
        prev_t = t
        plain.advance_time(dt)
        scrubbed.advance_time(dt)
        report = scrubbed.scrub_now()
        timeline.append({
            "t_s": t,
            "no_scrub_acc": _accuracy(plain, Xte, yte),
            "no_scrub_margin_min_v": _margin_min(plain),
            "scrub_acc": _accuracy(scrubbed, Xte, yte),
            "scrub_margin_min_v": _margin_min(scrubbed),
            "rows_refreshed": report.n_refreshed,
        })

    deg = scrubbed.metrics()["degradation"]
    wear = scrubbed.health()["degradation"]["wear"]
    summary = {
        "dataset": name,
        "fresh_accuracy": fresh,
        "no_scrub_final": timeline[-1]["no_scrub_acc"],
        "scrub_final": timeline[-1]["scrub_acc"],
        "scrub": deg,
        "wear_total_pulses": wear["total_pulses"],
        "timeline": timeline,
    }
    plain.close()
    scrubbed.close()

    # guardrail campaign acceptance: scrubbing holds accuracy flat while
    # the unscrubbed chip measurably degrades, and every refresh is
    # accounted for in both the energy report and the endurance ledger
    assert summary["scrub_final"] >= fresh - GUARDRAIL, summary
    assert summary["no_scrub_final"] <= fresh - COLLAPSE, summary
    assert deg["scrub_passes"] == len(CHECKPOINTS)
    assert deg["scrub_energy_j"] > 0.0 and deg["scrub_pulses"] > 0
    assert wear["total_pulses"] == deg["scrub_pulses"], (wear, deg)

    rows = [{"dataset": name, "t_s": f"{p['t_s']:.0e}",
             "no_scrub": f"{p['no_scrub_acc']:.4f}",
             "scrubbed": f"{p['scrub_acc']:.4f}",
             "refreshed": p["rows_refreshed"]} for p in timeline]
    return summary, rows


def run_chaos(name: str, *, s: int, seed: int, requests: int = 256) -> dict:
    """Scrub passes must never drop or double-resolve in-flight requests:
    hammer a background server with a request stream while a second thread
    forces scrub/advance cycles, then check every future resolved once."""
    c, (Xtr, ytr, Xte, yte) = compiled(name, s)
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, len(Xte), size=requests)
    server = _server(c, seed, background=True)
    stop = threading.Event()

    def _scrubber() -> None:
        while not stop.is_set():
            server.advance_time(2e5)
            server.scrub_now(force=True)

    th = threading.Thread(target=_scrubber, daemon=True)
    th.start()
    try:
        futs = [server.submit(Xte[i]) for i in idx]
        server.drain(timeout=120)
    finally:
        stop.set()
        th.join(timeout=30)
    resolved = [f for f in futs if f.done() and f.exception() is None]
    served = server.metrics()["requests_served"]
    scrub_passes = server.metrics()["degradation"]["scrub_passes"]
    server.close()
    assert len(resolved) == requests, (len(resolved), requests)
    assert served == requests, (served, requests)
    assert scrub_passes > 0, "chaos arm never scrubbed"
    return {"dataset": name, "requests": requests,
            "resolved_ok": len(resolved), "errors": 0,
            "scrubbed_during_serve": True}


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--datasets", nargs="+", default=["iris", "cancer"])
    ap.add_argument("--s", type=int, default=32)
    add_seed_arg(ap)
    ap.add_argument("--out", default=os.path.join(ART,
                                                  "degradation_bench.json"))
    args = ap.parse_args(argv)

    summaries, table = [], []
    for name in args.datasets:
        summary, rows = run_dataset(name, s=args.s, seed=args.seed)
        summaries.append(summary)
        table.extend(rows)
    chaos = run_chaos(args.datasets[0], s=args.s, seed=args.seed)

    emit(table, "degradation: accuracy vs drift horizon")
    for sm in summaries:
        print(f"{sm['dataset']:>8}: fresh {sm['fresh_accuracy']:.4f}  "
              f"no-scrub {sm['no_scrub_final']:.4f}  "
              f"scrubbed {sm['scrub_final']:.4f}  "
              f"refresh {sm['scrub']['scrub_energy_j'] * 1e9:.2f} nJ / "
              f"{sm['scrub']['scrub_pulses']} pulses")

    report = {
        "meta": {
            "datasets": list(args.datasets), "s": args.s, "seed": args.seed,
            "checkpoints_s": list(CHECKPOINTS),
            "guardrail": GUARDRAIL,
            "drift": {"nu": DRIFT.nu, "nu_sigma": DRIFT.nu_sigma,
                      "t0": DRIFT.t0,
                      "retention_tau_s": DRIFT.retention_tau_s},
        },
        "datasets": summaries,
        "chaos": chaos,
    }
    write_artifact(args.out, report)
    return report


if __name__ == "__main__":
    main()
