"""Paper Table V: LUT sizes and TCAM tile counts per dataset per S.

Runs the full DT-HW compiler on every Table II dataset (embedded Iris +
synthetic stand-ins, DESIGN.md §7) and reports LUT shape + N_rwd x N_cwd
tiles for S in {16, 32, 64, 128}, side by side with the paper's values.
"""
from repro.core import synthesize
from repro.dt import DATASETS

from .common import compiled, emit

SIZES = (16, 32, 64, 128)


def run() -> list[dict]:
    rows = []
    for name, spec in DATASETS.items():
        c, _ = compiled(name, 128)
        row = {
            "dataset": name,
            "lut_rows": c.lut.n_rows,
            "lut_width": c.lut.width,
            "paper_lut": f"{spec.paper_lut[0]}x{spec.paper_lut[1]}",
        }
        for s in SIZES:
            lay = synthesize(c.lut, s)
            row[f"tiles_S{s}"] = f"{lay.n_rwd}x{lay.n_cwd}"
        rows.append(row)
    return rows


def main():
    emit(run(), "Table V — LUT sizes and tile counts")


if __name__ == "__main__":
    main()
