"""Roofline analysis (deliverable g).

Reads the dry-run artifacts (``artifacts/dryrun/*.json`` + partitioned HLO)
and derives, per (arch × shape × mesh):

  compute term    = dot_FLOPs_per_device / peak_FLOPs      (197 TFLOP/s bf16)
  memory term     = HBM_bytes_per_device / HBM_bw          (819 GB/s)
  collective term = collective_bytes_per_device / link_bw  (50 GB/s ICI;
                    pod-axis collectives would ride DCN — single-pod table)

dot_FLOPs / collective bytes / HBM bytes are **loop-corrected** via the HLO
analyzer (benchmarks/hlo_analysis.py): XLA cost_analysis counts while bodies
once, so scanned layers/microbatches/chunks would otherwise be undercounted
by 10-1000x.  The raw cost_analysis numbers are retained in the JSON
artifacts for reference.

MODEL_FLOPS (the useful-work numerator) is analytic:
  train   3 x (2·N_active·T + A)      (fwd + 2x bwd; remat NOT counted)
  prefill     2·N_active·T + A
  decode      2·N_active·B + A_dec
  A (causal attention, useful half) = Σ_attn_layers 2·B·S²·H·hd
  A_dec = Σ_attn_layers 4·B·S_cache·H·hd

Usage: python -m benchmarks.roofline [--mesh singlepod|multipod] [--md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs import ARCHS, SHAPES, get_config, shape_cells

from .hlo_analysis import analyze_file

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
LINK_BW = 50e9           # bytes/s per ICI link

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")

__all__ = ["model_flops", "cell_rows", "main"]


def model_flops(cfg, shape) -> float:
    """Analytic useful FLOPs per step (global)."""
    n_act = cfg.n_active_params()
    b, s = shape.global_batch, shape.seq_len
    attn_layers = sum(
        kind.split("+")[0] in ("attn", "swa") for kind in cfg.pattern
    ) * cfg.n_repeat
    hhd = cfg.n_heads * cfg.head_dim
    if shape.step == "train":
        tokens = b * s
        window = cfg.sliding_window or s
        a = attn_layers * 2.0 * b * s * min(s, window) * hhd
        return 3.0 * (2.0 * n_act * tokens + a)
    if shape.step == "prefill":
        tokens = b * s
        window = cfg.sliding_window or s
        a = attn_layers * 2.0 * b * s * min(s, window) * hhd
        return 2.0 * n_act * tokens + a
    # decode: one token against an S-length cache
    window = cfg.sliding_window or s
    a = attn_layers * 4.0 * b * min(s, window) * hhd
    return 2.0 * n_act * b + a


def cell_rows(mesh_tag: str = "singlepod") -> list[dict]:
    rows = []
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in shape_cells(arch):
            base = f"{arch}__{shape.name}__{mesh_tag}"
            jpath = os.path.join(ART, base + ".json")
            hpath = os.path.join(ART, base + ".hlo.gz")
            if not (os.path.exists(jpath) and os.path.exists(hpath)):
                continue
            rec = json.load(open(jpath))
            cost = analyze_file(hpath)
            n_dev = rec["n_devices"]
            t_c = cost.dot_flops / PEAK_FLOPS
            t_m = cost.hbm_bytes / HBM_BW
            t_x = cost.collective_bytes / LINK_BW
            dom = max(("compute", t_c), ("memory", t_m),
                      ("collective", t_x), key=lambda kv: kv[1])[0]
            mf = model_flops(cfg, shape) / n_dev
            ratio = mf / cost.dot_flops if cost.dot_flops else 0.0
            bound = max(t_c, t_m, t_x)
            rows.append({
                "arch": arch,
                "shape": shape.name,
                "step": shape.step,
                "compute_s": t_c,
                "memory_s": t_m,
                "collective_s": t_x,
                "dominant": dom,
                "hlo_tflops_dev": cost.dot_flops / 1e12,
                "model_tflops_dev": mf / 1e12,
                "useful_ratio": ratio,
                "roofline_frac": (mf / PEAK_FLOPS) / bound if bound else 0.0,
                "mem_gib_dev": (rec["memory"]["argument_bytes"]
                                + rec["memory"]["temp_bytes"]) / 2**30,
                "coll_gb_dev": cost.collective_bytes / 1e9,
            })
    return rows


def _fmt(rows, md=False):
    hdr = ["arch", "shape", "compute_s", "memory_s", "collective_s",
           "dominant", "model_tflops_dev", "useful_ratio", "roofline_frac",
           "mem_gib_dev"]
    out = []
    if md:
        out.append("| " + " | ".join(hdr) + " |")
        out.append("|" + "---|" * len(hdr))
    else:
        out.append(",".join(hdr))
    for r in rows:
        vals = [r["arch"], r["shape"], f"{r['compute_s']:.4f}",
                f"{r['memory_s']:.4f}", f"{r['collective_s']:.4f}",
                r["dominant"], f"{r['model_tflops_dev']:.1f}",
                f"{r['useful_ratio']:.3f}", f"{r['roofline_frac']:.3f}",
                f"{r['mem_gib_dev']:.1f}"]
        out.append(("| " + " | ".join(vals) + " |") if md
                   else ",".join(vals))
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="singlepod",
                    choices=["singlepod", "multipod"])
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    rows = cell_rows(args.mesh)
    print(f"### Roofline — {args.mesh} "
          f"(197 TF/s bf16, 819 GB/s HBM, 50 GB/s ICI)")
    print(_fmt(rows, md=args.md))


if __name__ == "__main__":
    main()
