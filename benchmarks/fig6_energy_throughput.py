"""Paper Fig 6: (a) energy/decision vs throughput per dataset per S,
(b) EDP vs S, (c) % EDP reduction from selective precharge.

Large datasets evaluate on a subsample of test inputs (energy is a mean per
decision; the paper also reports means).
"""
import numpy as np

from repro.core import synthesize
from repro.core import encode_inputs, simulate

from .common import compiled, emit

SIZES = (16, 32, 64, 128)
MAX_EVAL = 512


def run(datasets=None) -> list[dict]:
    from repro.dt import DATASETS
    rows = []
    for name in datasets or DATASETS:
        c, (Xtr, ytr, Xte, yte) = compiled(name, 128)
        n = min(MAX_EVAL, len(Xte))
        xb = encode_inputs(c.lut, Xte[:n])
        for s in SIZES:
            lay = synthesize(c.lut, s)
            res = simulate(lay, xb)
            res_nosp = simulate(lay, xb, selective_precharge=False)
            edp = res.mean_energy * (1.0 / res.throughput_seq)
            edp_nosp = res_nosp.mean_energy * (1.0 / res_nosp.throughput_seq)
            rows.append({
                "dataset": name,
                "S": s,
                "energy_nj_per_dec": round(res.mean_energy * 1e9, 5),
                "throughput_mdec_s": round(res.throughput_seq / 1e6, 3),
                "throughput_pipe_mdec_s": round(res.throughput_pipe / 1e6, 2),
                "edp_j_s": f"{edp:.3e}",
                "sp_edp_reduction_pct": round(100 * (1 - edp / edp_nosp), 2),
                "tiles": f"{lay.n_rwd}x{lay.n_cwd}",
                "accuracy": round(res.accuracy(yte[:n]), 4),
            })
    return rows


def main():
    emit(run(), "Fig 6 — energy / throughput / EDP / SP reduction")


if __name__ == "__main__":
    main()
