"""Multi-bank forest scaling benchmark: compile one bagged forest, then run
its first 1/2/4/8 banks through ``repro.ForestExecutor`` and record how both
the *modelled* pipelined throughput (sum of per-bank f_max / II, from the
analog ReCAM model) and the *measured* host throughput scale with bank
count.  Dumps ``artifacts/forest_bench.json``; the modelled aggregate dec/s
series must be strictly increasing in bank count (asserted — it is the
paper's multi-array pipelining story).

    PYTHONPATH=src python -m benchmarks.forest_bench [--banks 1 2 4 8]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro import ForestExecutor, compile_forest, forest_infer_ref, train_forest
from repro.dt import load_split

from .common import ART, emit


def run(
    dataset: str = "cancer",
    *,
    banks: tuple[int, ...] = (1, 2, 4, 8),
    s: int = 128,
    batch: int = 256,
    repeats: int = 5,
    engine: str = "banked",
    seed: int = 0,
) -> dict:
    Xtr, ytr, Xte, yte = load_split(dataset)
    trees = train_forest(Xtr, ytr, n_trees=max(banks), max_depth=8, seed=seed)
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, len(Xte), size=batch)
    Xq, yq = Xte[idx], yte[idx]

    rows = []
    for n in banks:
        forest = compile_forest(trees[:n], s=s)
        ex = ForestExecutor(forest, engine=engine)
        compiles = ex.warmup(batch)
        # measured: median wall time over repeats (post-warmup, steady state)
        times = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            res = ex.infer(Xq)
            times.append(time.perf_counter() - t0)
        wall = float(np.median(times))
        ref = forest_infer_ref(forest, Xq)
        agg = res.figures["aggregate"]
        rows.append({
            "n_banks": n,
            "n_groups": ex.plan.n_groups,
            "rows_total": sum(int(l.cells.shape[0]) for l in forest.layouts),
            "engine": engine,
            "jit_compiles": compiles,
            "wall_s": wall,
            "measured_decs_per_s": n * batch / wall,
            "modelled_decs_pipe": agg["decs_pipe"],
            "modelled_ensemble_decs_pipe": agg["ensemble_decs_pipe"],
            "modelled_latency_s": agg["latency_s"],
            "area_mm2": agg["area_m2"] * 1e6,
            "energy_nj_per_dec": agg.get("energy_per_dec_j", 0.0) * 1e9,
            "accuracy": float((res.predictions == yq).mean()),
            "parity_with_ref": bool(
                (res.predictions == ref.predictions).all()
            ),
        })

    series = [r["modelled_decs_pipe"] for r in rows]
    monotone = all(b > a for a, b in zip(series, series[1:]))
    assert monotone, f"modelled dec/s not increasing with banks: {series}"
    return {
        "dataset": dataset,
        "s": s,
        "batch": batch,
        "seed": seed,
        "banks": rows,
        "modelled_decs_pipe_monotone": monotone,
    }


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="cancer")
    ap.add_argument("--banks", nargs="+", type=int, default=[1, 2, 4, 8])
    ap.add_argument("--s", type=int, default=128)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--engine", default="banked")
    ap.add_argument("--seed", type=int, default=0,
                    help="forest training + query sampling seed (the "
                         "artifact JSON is reproducible run-to-run)")
    ap.add_argument("--out", default=os.path.join(ART, "forest_bench.json"))
    args = ap.parse_args(argv)

    report = run(args.dataset, banks=tuple(args.banks), s=args.s,
                 batch=args.batch, repeats=args.repeats, engine=args.engine,
                 seed=args.seed)
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    emit(report["banks"], f"forest_bench[{args.dataset}]")
    for r in report["banks"]:
        print(f"banks={r['n_banks']:2d}: modelled "
              f"{r['modelled_decs_pipe'] / 1e6:9.1f} Mdec/s  measured "
              f"{r['measured_decs_per_s']:10.0f} dec/s  "
              f"acc {r['accuracy']:.4f}  parity {r['parity_with_ref']}")
    print(f"# wrote {args.out}")
    return report


if __name__ == "__main__":
    main()
