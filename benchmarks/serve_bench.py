"""Serving-engine load benchmark: push a randomized request stream through
``repro.serve.TCAMServer``, print wall-clock throughput/latency to stdout,
and dump the seed-deterministic portion of the report (accuracy, request
counters, modelled ReCAM energy/throughput, layout geometry) as JSON to
``artifacts/serve_bench.json`` — same flags + same ``--seed`` produce a
byte-identical artifact.

    PYTHONPATH=src python -m benchmarks.serve_bench [--requests 2048] [--seed 0]
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

from repro.dt import load_split
from repro.serve import ServeConfig, TCAMServer

from .common import ART, add_seed_arg, compiled, write_artifact

# Keys of the metrics snapshot that are a pure function of (flags, seed):
# request stream, accuracy, modelled energy per decision, and layout-derived
# hardware figures.  Batching/latency/jit counters depend on wall-clock batch
# formation and stay out of the artifact.
DETERMINISTIC_KEYS = (
    "dataset", "s", "engine", "buckets",
    "requests_enqueued", "requests_served", "accuracy",
    "modelled_nj_per_dec", "active_evals",
    "modelled_mdecs_seq", "modelled_mdecs_pipe", "layout",
)


def run(
    datasets: tuple[str, ...] = ("iris", "cancer", "covid"),
    *,
    requests: int = 2048,
    s: int = 64,
    max_batch: int = 128,
    max_delay_ms: float = 2.0,
    engine: str = "auto",
    seed: int = 0,
) -> list[dict]:
    reports = []
    rng = np.random.default_rng(seed)
    for name in datasets:
        c, (Xtr, ytr, Xte, yte) = compiled(name, s)
        cfg = ServeConfig(max_batch=max_batch, max_delay_s=max_delay_ms / 1e3,
                          engine=engine)
        # randomized arrival order + duplicate queries, like real traffic
        idx = rng.integers(0, len(Xte), size=requests)
        t0 = time.perf_counter()
        with TCAMServer(c, config=cfg) as server:
            server.warmup()
            results = server.serve(Xte[idx])
            stats = server.metrics()
        wall = time.perf_counter() - t0
        preds = np.array([r.prediction for r in results])
        stats.update(
            dataset=name,
            s=s,
            wall_s=wall,
            throughput_rps=len(results) / wall,
            accuracy=float((preds == yte[idx]).mean()),
        )
        reports.append(stats)
    return reports


def main(argv=None) -> list[dict]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--datasets", nargs="+", default=["iris", "cancer", "covid"])
    ap.add_argument("--requests", type=int, default=2048)
    ap.add_argument("--s", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=128)
    ap.add_argument("--max-delay-ms", type=float, default=2.0)
    ap.add_argument("--engine", default="auto")
    add_seed_arg(ap)
    ap.add_argument("--out", default=os.path.join(ART, "serve_bench.json"))
    args = ap.parse_args(argv)

    reports = run(tuple(args.datasets), requests=args.requests, s=args.s,
                  max_batch=args.max_batch, max_delay_ms=args.max_delay_ms,
                  engine=args.engine, seed=args.seed)
    artifact = {
        "meta": {
            "datasets": list(args.datasets), "requests": args.requests,
            "s": args.s, "max_batch": args.max_batch,
            "max_delay_ms": args.max_delay_ms, "engine": args.engine,
            "seed": args.seed,
        },
        "results": [
            {k: r[k] for k in DETERMINISTIC_KEYS if k in r} for r in reports
        ],
    }
    for r in reports:
        print(f"{r['dataset']:>8}: {r['throughput_rps']:8.0f} req/s  "
              f"total p50/p99 {r['total_latency']['p50_ms']:6.2f}/"
              f"{r['total_latency']['p99_ms']:6.2f} ms  "
              f"fill {r['mean_batch_fill']:.2f}  "
              f"compiles {r['jit_cache']['misses']}  "
              f"{r['modelled_nj_per_dec']:.4f} nJ/dec  "
              f"acc {r['accuracy']:.4f}")
    write_artifact(args.out, artifact)
    return reports


if __name__ == "__main__":
    main()
