"""Serving-engine load benchmark: push a randomized request stream through
``repro.serve.TCAMServer`` and dump a JSON report (throughput, p50/p99
queue/compute/total latency, batch fill, jit compile counts, modelled ReCAM
energy/throughput) to ``artifacts/serve_bench.json``.

    PYTHONPATH=src python -m benchmarks.serve_bench [--requests 2048]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.dt import load_split
from repro.serve import ServeConfig, TCAMServer

from .common import ART, compiled


def run(
    datasets: tuple[str, ...] = ("iris", "cancer", "covid"),
    *,
    requests: int = 2048,
    s: int = 64,
    max_batch: int = 128,
    max_delay_ms: float = 2.0,
    engine: str = "auto",
    seed: int = 0,
) -> list[dict]:
    reports = []
    rng = np.random.default_rng(seed)
    for name in datasets:
        c, (Xtr, ytr, Xte, yte) = compiled(name, s)
        cfg = ServeConfig(max_batch=max_batch, max_delay_s=max_delay_ms / 1e3,
                          engine=engine)
        # randomized arrival order + duplicate queries, like real traffic
        idx = rng.integers(0, len(Xte), size=requests)
        t0 = time.perf_counter()
        with TCAMServer(c, config=cfg) as server:
            server.warmup()
            results = server.serve(Xte[idx])
            stats = server.metrics()
        wall = time.perf_counter() - t0
        preds = np.array([r.prediction for r in results])
        stats.update(
            dataset=name,
            s=s,
            wall_s=wall,
            throughput_rps=len(results) / wall,
            accuracy=float((preds == yte[idx]).mean()),
        )
        reports.append(stats)
    return reports


def main(argv=None) -> list[dict]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--datasets", nargs="+", default=["iris", "cancer", "covid"])
    ap.add_argument("--requests", type=int, default=2048)
    ap.add_argument("--s", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=128)
    ap.add_argument("--max-delay-ms", type=float, default=2.0)
    ap.add_argument("--engine", default="auto")
    ap.add_argument("--out", default=os.path.join(ART, "serve_bench.json"))
    args = ap.parse_args(argv)

    reports = run(tuple(args.datasets), requests=args.requests, s=args.s,
                  max_batch=args.max_batch, max_delay_ms=args.max_delay_ms,
                  engine=args.engine)
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(reports, f, indent=2)
    for r in reports:
        print(f"{r['dataset']:>8}: {r['throughput_rps']:8.0f} req/s  "
              f"total p50/p99 {r['total_latency']['p50_ms']:6.2f}/"
              f"{r['total_latency']['p99_ms']:6.2f} ms  "
              f"fill {r['mean_batch_fill']:.2f}  "
              f"compiles {r['jit_cache']['misses']}  "
              f"{r['modelled_nj_per_dec']:.4f} nJ/dec  "
              f"acc {r['accuracy']:.4f}")
    print(f"# wrote {args.out}")
    return reports


if __name__ == "__main__":
    main()
