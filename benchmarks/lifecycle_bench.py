"""Model lifecycle benchmark: delta reprogramming savings + zero-downtime
hot swap under load.

Scenario (one JSON report, CI artifact):

1. **Retrain** — v1 is trained on the dataset; v2 on a noise-perturbed copy
   (the production "model drifted, retrain and redeploy" event).  Both are
   published to a ``ModelRegistry`` with lineage v1 -> v2.
2. **Delta vs full reprogramming** — ``plan_delta`` must write strictly
   fewer cells than the naive erase-then-program pass (asserted), with
   modelled write energy / program time / endurance consumption from
   ``reprogram_figures`` for both, plus the wear-leveled variant
   (``wear_level_rows``) and the chip's cumulative ``WearTracker`` ledger.
3. **Hot swap under load** — a background ``TCAMServer`` takes ``--requests``
   requests; mid-stream v2 is staged (mirroring live traffic) and promoted.
   Asserted: *every* submitted future resolves with a result (zero dropped,
   zero errors), and the promoted server's predictions are bit-exact against
   v2's functional-sim reference path.

    PYTHONPATH=src python -m benchmarks.lifecycle_bench [--seed 0]
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import time

import numpy as np

from repro import (
    DT2CAM,
    LifecycleManager,
    ModelRegistry,
    ServeConfig,
    TCAMServer,
    WearTracker,
    encode_inputs,
    plan_delta,
    plan_full,
    simulate,
    wear_level_rows,
)
from repro.dt import load_split

from .common import ART, emit


def _retrained_pair(dataset: str, s: int, seed: int):
    """v1 on the clean split, v2 on feature-noise-perturbed training data
    (same labels) — a realistic drift-retrain delta, not a toy bitflip."""
    Xtr, ytr, Xte, yte = load_split(dataset)
    rng = np.random.default_rng(seed)
    scale = 0.1 * Xtr.std(axis=0, keepdims=True)
    Xtr2 = Xtr + rng.normal(0.0, 1.0, size=Xtr.shape) * scale
    v1 = DT2CAM(s=s, max_depth=8).fit(Xtr, ytr)
    v2 = DT2CAM(s=s, max_depth=8).fit(Xtr2, ytr)
    return v1, v2, (Xtr, ytr, Xte, yte)


def reprogram_study(v1, v2, registry: ModelRegistry, dataset: str) -> dict:
    """Publish lineage, plan delta/full/wear-leveled passes, model energy."""
    r1 = registry.publish(v1.compiled, dataset, metadata={"gen": 1})
    r2 = registry.publish(v2.compiled, dataset,
                          parents=[r1.version_id], metadata={"gen": 2})
    old_lay, new_lay = v1.compiled.layout, v2.compiled.layout

    delta = plan_delta(old_lay.cells, new_lay.cells,
                       old_class_bits=old_lay.class_bits,
                       new_class_bits=new_lay.class_bits)
    full = plan_full(old_lay.cells, new_lay.cells,
                     old_class_bits=old_lay.class_bits,
                     new_class_bits=new_lay.class_bits)
    assert delta.n_cells_written < full.n_cells_written, (
        f"delta ({delta.n_cells_written} cells) must write strictly fewer "
        f"cells than full reprogramming ({full.n_cells_written})"
    )

    # wear-leveled placement: same candidate, rows re-placed to minimise
    # pulses against the live grid (and spread endurance consumption)
    wear = WearTracker()
    wear.record(plan_full(np.zeros((0, 0), np.int8), old_lay.cells,
                          new_class_bits=old_lay.class_bits))
    remap = wear_level_rows(new_lay, old_lay.cells, wear)
    leveled = plan_delta(old_lay.cells, remap.layout.cells,
                         old_class_bits=old_lay.class_bits,
                         new_class_bits=remap.layout.class_bits)
    wear.record(leveled)

    return {
        "versions": {
            "v1": r1.version_id, "v2": r2.version_id,
            "lineage": [v.version_id
                        for v in registry.lineage(r2.version_id)],
        },
        "delta": {**delta.summary(), "figures": delta.figures()},
        "full": {**full.summary(), "figures": full.figures()},
        "wear_leveled_delta": {**leveled.summary(),
                               "figures": leveled.figures(),
                               "remap": remap.summary()},
        "cells_saved": full.n_cells_written - delta.n_cells_written,
        "energy_saving_x": (full.figures()["energy_j"]
                            / max(delta.figures()["energy_j"], 1e-30)),
        "wear": wear.snapshot(),
    }


def hot_swap_under_load(v1, v2, registry: ModelRegistry, dataset: str,
                        data, *, n_requests: int, seed: int) -> dict:
    """Stage + promote v2 while a background server is taking traffic."""
    Xtr, ytr, Xte, yte = data
    rng = np.random.default_rng(seed)
    Xq = Xte[rng.integers(0, len(Xte), size=n_requests)]

    r1 = registry.publish(v1.compiled, dataset)
    r2 = registry.publish(v2.compiled, dataset, parents=[r1.version_id])

    cfg = ServeConfig(engine="ref", max_batch=64, max_delay_s=0.001,
                      background=True)
    srv = TCAMServer(v1.compiled, config=cfg,
                     rng=np.random.default_rng(seed))
    mgr = LifecycleManager(registry, srv, live_version=r1.version_id)

    stage_at, promote_at = n_requests // 4, n_requests // 2
    futs = []
    promotion = None
    t0 = time.perf_counter()
    for i, x in enumerate(Xq):
        futs.append(srv.submit(x))
        if i == stage_at:
            mgr.stage(r2.version_id, mirror_fraction=0.5)
        elif i >= promote_at and promotion is None:
            # a retrained model legitimately disagrees with v1 on live
            # traffic — the operator tolerance is wide open here; the
            # correctness gate is the candidate's own canary
            rep = mgr.promote(min_shadow_batches=1, max_disagreement=1.0)
            if not rep.staged:      # gate actually evaluated
                promotion = rep
                assert rep.promoted, f"promotion failed: {rep.reason}"
    srv.drain(timeout=120.0)
    wall = time.perf_counter() - t0
    if promotion is None:          # not enough mirrored batches mid-stream
        promotion = mgr.promote(min_shadow_batches=0, max_disagreement=1.0)
        assert promotion.promoted, f"promotion failed: {promotion.reason}"

    dropped = sum(1 for f in futs if not f.done())
    errors = sum(1 for f in futs if f.done() and f.exception() is not None)
    assert dropped == 0, f"{dropped} requests never resolved across the swap"
    assert errors == 0, f"{errors} requests errored across the swap"

    # promoted model must be bit-exact against v2's functional-sim reference
    n_check = min(256, len(Xte))
    served = np.array([r.prediction for r in srv.serve(Xte[:n_check])])
    ref = simulate(v2.compiled.layout,
                   encode_inputs(v2.compiled.lut, Xte[:n_check])).predictions
    assert np.array_equal(served, ref), \
        "promoted model is not bit-exact vs its simulate() reference"

    metrics = srv.metrics()
    srv.close()
    return {
        "n_requests": n_requests,
        "wall_s": wall,
        "dropped": dropped,
        "errors": errors,
        "promotion": promotion.summary(),
        "post_promotion_bit_exact": True,
        "lifecycle_metrics": metrics["lifecycle"],
        "live_version": mgr.live_version,
        "acc_v1": float((np.asarray([
            int(p) for p in simulate(
                v1.compiled.layout,
                encode_inputs(v1.compiled.lut, Xte)).predictions
        ]) == yte).mean()),
        "acc_v2": float((served == yte[:n_check]).mean()),
    }


def run(dataset: str = "cancer", *, s: int = 128, n_requests: int = 1000,
        seed: int = 0, registry_root: str | None = None) -> dict:
    root = registry_root or os.path.join(ART, "lifecycle_registry")
    shutil.rmtree(root, ignore_errors=True)
    registry = ModelRegistry(root)
    v1, v2, data = _retrained_pair(dataset, s, seed)
    report = {
        "dataset": dataset,
        "s": s,
        "seed": seed,
        "reprogramming": reprogram_study(v1, v2, registry, dataset),
        "hot_swap": hot_swap_under_load(
            v1, v2, registry, dataset, data,
            n_requests=n_requests, seed=seed,
        ),
    }
    return report


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="cancer")
    ap.add_argument("--s", type=int, default=128)
    ap.add_argument("--requests", type=int, default=1000)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=os.path.join(ART, "lifecycle_bench.json"))
    args = ap.parse_args(argv)

    report = run(args.dataset, s=args.s, n_requests=args.requests,
                 seed=args.seed)
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)

    rp = report["reprogramming"]
    emit([{"delta_cells": rp["delta"]["cells_written"],
           "full_cells": rp["full"]["cells_written"]}],
         f"lifecycle_bench[{args.dataset}]")
    print(f"delta writes {rp['delta']['cells_written']} cells "
          f"({rp['delta']['figures']['energy_j'] * 1e9:.2f} nJ) vs full "
          f"{rp['full']['cells_written']} "
          f"({rp['full']['figures']['energy_j'] * 1e9:.2f} nJ) — "
          f"{rp['energy_saving_x']:.1f}x energy saving")
    hs = report["hot_swap"]
    print(f"hot swap: {hs['n_requests']} requests, dropped={hs['dropped']} "
          f"errors={hs['errors']} promoted={hs['promotion']['promoted']} "
          f"bit_exact={hs['post_promotion_bit_exact']}")
    print(f"# wrote {args.out}")
    return report


if __name__ == "__main__":
    main()
