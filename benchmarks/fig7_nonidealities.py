"""Paper Fig 7/8: % accuracy loss under hardware non-idealities
(SAF stuck-at faults, SA reference-voltage variability, input noise) for
Diabetes / Cancer / Covid at two tile sizes."""
import numpy as np

from repro.core import synthesize
from repro.core import apply_saf, encode_inputs, noisy_inputs, simulate
from repro.core import predict

from .common import compiled, emit

DATASETS = ("diabetes", "cancer", "covid")
SIZES = (32, 128)
SAF = (0.0, 0.001, 0.005, 0.01, 0.05)
SA_SIGMA = (0.0, 0.03, 0.05, 0.1)
IN_SIGMA = (0.0, 0.005, 0.01, 0.05, 0.1)
TRIALS = 3
MAX_EVAL = 400


def run(datasets=DATASETS, trials=TRIALS) -> list[dict]:
    rows = []
    for name in datasets:
        c, (Xtr, ytr, Xte, yte) = compiled(name, 128)
        n = min(MAX_EVAL, len(Xte))
        Xe, ye = Xte[:n], yte[:n]
        golden = float((predict(c.tree, Xe) == ye).mean())
        for s in SIZES:
            lay = synthesize(c.lut, s)
            xb = encode_inputs(c.lut, Xe)

            def acc_loss(p_saf=0.0, sa_sigma=0.0, sigma_in=0.0):
                accs = []
                for t in range(trials):
                    rng = np.random.default_rng(1000 * t + 7)
                    lay_t = lay
                    if p_saf:
                        import dataclasses
                        lay_t = dataclasses.replace(
                            lay, cells=apply_saf(lay.cells, p_saf, p_saf, rng))
                    xb_t = (encode_inputs(c.lut, noisy_inputs(Xe, sigma_in,
                                                              rng))
                            if sigma_in else xb)
                    res = simulate(lay_t, xb_t, sa_sigma=sa_sigma, rng=rng)
                    accs.append(res.accuracy(ye))
                return 100.0 * (golden - float(np.mean(accs)))

            for p in SAF:
                rows.append({"dataset": name, "S": s, "knob": "SAF_pct",
                             "value": p * 100,
                             "acc_loss_pct": round(acc_loss(p_saf=p), 3)})
            for sg in SA_SIGMA:
                rows.append({"dataset": name, "S": s, "knob": "sa_sigma_V",
                             "value": sg,
                             "acc_loss_pct": round(acc_loss(sa_sigma=sg), 3)})
            for si in IN_SIGMA:
                rows.append({"dataset": name, "S": s, "knob": "in_sigma",
                             "value": si,
                             "acc_loss_pct": round(acc_loss(sigma_in=si), 3)})
    return rows


def main():
    emit(run(), "Fig 7 — accuracy loss under non-idealities")


if __name__ == "__main__":
    main()
