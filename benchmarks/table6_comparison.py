"""Paper Table VI: comparison against SOTA DT accelerators on the
traffic-dataset-scale problem (2000 rows x 256 features x 8 bits -> 2048-bit
LUT, S = 128 tiles).

We synthesize the workload exactly as the paper describes: a 2000-path tree
over 256 features with 8-bit (7-threshold) quantized features, compile it
with the DT-HW pipeline, and run the functional simulator on random inputs.
The competitor rows are the paper's reported numbers.
"""
import os

import numpy as np

from repro.core import compile_tree, train_tree
from repro.core import DEFAULT_HW, encode_inputs, f_max, simulate

from .common import ART, emit

# Accelerator, technology nm, f_clk GHz, throughput dec/s, energy nJ/dec,
# area mm^2, area/bit um^2 — from the paper's Table VI
PAPER_ROWS = [
    ("ASIC [17]", 65, 0.2, 30, 186.7e3, None, None),
    ("ASIC [39]", 65, 0.25, 60, 460e3, None, None),
    ("ASIC IMC [20]", 65, 1.0, 364.4e3, 19.4, None, None),
    ("ACAM [15]", 16, 1.0, 20.8e6, 0.17, 0.266, 0.299),
    ("P-ACAM [15]", 16, 1.0, 333e6, 0.17, 0.266, 0.299),
]
PAPER_DT2CAM = {"throughput": 58.8e6, "energy_nj": 0.098, "area_mm2": 0.07,
                "area_per_bit": 0.017}


def _traffic_like_tree():
    """2000-leaf tree over 256 features quantized to 8 levels."""
    path = os.path.join(ART, "trees", "traffic2000.npz")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    if os.path.exists(path):
        z = np.load(path)
        from repro.core import DecisionTree
        return DecisionTree(z["feature"], z["threshold"], z["left"],
                            z["right"], z["value"], 256, 8)
    rng = np.random.default_rng(0)
    n = 60_000
    X = np.floor(rng.uniform(0, 8, size=(n, 256)))
    # planted rules over a few features + noise for a bushy tree
    y = ((X[:, 0] > 3).astype(int) * 4 + (X[:, 1] > 5).astype(int) * 2
         + (X[:, 2] > 2).astype(int)).astype(np.int64)
    flip = rng.random(n) < 0.35
    y[flip] = rng.integers(0, 8, size=int(flip.sum()))
    tree = train_tree(X, y, max_depth=40, max_leaves=2000)
    np.savez(path, feature=tree.feature, threshold=tree.threshold,
             left=tree.left, right=tree.right, value=tree.value)
    return tree


def run(n_inputs: int = 256) -> list[dict]:
    tree = _traffic_like_tree()
    c = compile_tree(tree, 128)
    rng = np.random.default_rng(1)
    X = np.floor(rng.uniform(0, 8, size=(n_inputs, 256)))
    xb = encode_inputs(c.lut, X)
    res = simulate(c.layout, xb)
    area = c.layout.area_m2() * 1e6          # m^2 -> mm^2
    area_bit = area * 1e6 / c.layout.n_cells  # um^2 / cell

    rows = []
    for name, tech, fclk, thr, e_nj, a, ab in PAPER_ROWS:
        edp = e_nj * 1e-9 * (1.0 / thr)
        rows.append({
            "accelerator": name, "tech_nm": tech, "f_clk_ghz": fclk,
            "throughput_dec_s": f"{thr:.3g}",
            "energy_nj_dec": e_nj,
            "area_mm2": a if a is not None else "-",
            "area_um2_bit": ab if ab is not None else "-",
            "fom_j_s_mm2": f"{edp * a:.3g}" if a else "-",
        })
    for name, thr in (("DT2CAM_128 (ours)", res.throughput_seq),
                      ("P-DT2CAM_128 (ours)", res.throughput_pipe)):
        e_nj = res.mean_energy * 1e9
        edp = res.mean_energy / thr
        rows.append({
            "accelerator": name, "tech_nm": 16, "f_clk_ghz": round(
                f_max(128) / 1e9, 3),
            "throughput_dec_s": f"{thr:.3g}",
            "energy_nj_dec": round(e_nj, 4),
            "area_mm2": round(area, 4),
            "area_um2_bit": round(area_bit, 4),
            "fom_j_s_mm2": f"{edp * area:.3g}",
        })
    rows.append({
        "accelerator": "paper DT2CAM_128 (reference)", "tech_nm": 16,
        "f_clk_ghz": 1.0,
        "throughput_dec_s": f"{PAPER_DT2CAM['throughput']:.3g}",
        "energy_nj_dec": PAPER_DT2CAM["energy_nj"],
        "area_mm2": PAPER_DT2CAM["area_mm2"],
        "area_um2_bit": PAPER_DT2CAM["area_per_bit"],
        "fom_j_s_mm2": "1.22e-19",
    })
    return rows


def main():
    emit(run(), "Table VI — SOTA comparison (traffic-scale LUT, S=128)")


if __name__ == "__main__":
    main()
