"""Shared benchmark infrastructure: dataset -> fitted/compiled DT2CAM with
on-disk tree caching (Credit takes ~10s to fit; cache under artifacts/),
plus the seeding / artifact-writing conventions every benchmark follows:
a ``--seed`` flag (``add_seed_arg``) and a JSON artifact whose content is
fully seed-determined — wall-clock numbers go to stdout, never into the
file (``write_artifact``), so same flags + same seed => byte-identical
artifact."""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import DT2CAM, DecisionTree, compile_tree, train_tree
from repro.dt import DATASETS, load_split

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")
TREES = os.path.join(ART, "trees")

__all__ = ["fitted_tree", "compiled", "ART", "emit", "add_seed_arg",
           "write_artifact"]


def add_seed_arg(ap, default: int = 0) -> None:
    """The shared ``--seed`` flag: one integer seeding every RNG the
    benchmark touches, making the artifact JSON reproducible."""
    ap.add_argument(
        "--seed", type=int, default=default,
        help="RNG seed; same flags + same seed -> byte-identical artifact",
    )


def write_artifact(path: str, report) -> None:
    """Write a benchmark report as indented JSON (CI artifact).  Callers
    must keep wall-clock-dependent values out of ``report`` — print those
    to stdout instead — so the artifact stays seed-deterministic."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"# wrote {path}")


def fitted_tree(name: str) -> tuple[DecisionTree, tuple]:
    spec = DATASETS[name]
    os.makedirs(TREES, exist_ok=True)
    path = os.path.join(TREES, f"{name}.npz")
    Xtr, ytr, Xte, yte = load_split(name)
    if os.path.exists(path):
        z = np.load(path)
        tree = DecisionTree(z["feature"], z["threshold"], z["left"],
                            z["right"], z["value"], int(z["n_features"]),
                            int(z["n_classes"]))
    else:
        tree = train_tree(Xtr, ytr, max_depth=spec.max_depth,
                          max_leaves=spec.max_leaves,
                          min_samples_leaf=spec.min_samples_leaf)
        np.savez(path, feature=tree.feature, threshold=tree.threshold,
                 left=tree.left, right=tree.right, value=tree.value,
                 n_features=tree.n_features, n_classes=tree.n_classes)
    return tree, (Xtr, ytr, Xte, yte)


def compiled(name: str, s: int):
    tree, data = fitted_tree(name)
    return compile_tree(tree, s), data


def emit(rows: list[dict], name: str) -> None:
    """Print a benchmark table as CSV (name,key=value CSV convention)."""
    if not rows:
        return
    keys = list(rows[0].keys())
    print(f"### {name}")
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r[k]) for k in keys))
    print()
