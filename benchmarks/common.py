"""Shared benchmark infrastructure: dataset -> fitted/compiled DT2CAM with
on-disk tree caching (Credit takes ~10s to fit; cache under artifacts/)."""
from __future__ import annotations

import os
import time

import numpy as np

from repro.core import DT2CAM, DecisionTree, compile_tree, train_tree
from repro.dt import DATASETS, load_split

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")
TREES = os.path.join(ART, "trees")

__all__ = ["fitted_tree", "compiled", "ART", "emit"]


def fitted_tree(name: str) -> tuple[DecisionTree, tuple]:
    spec = DATASETS[name]
    os.makedirs(TREES, exist_ok=True)
    path = os.path.join(TREES, f"{name}.npz")
    Xtr, ytr, Xte, yte = load_split(name)
    if os.path.exists(path):
        z = np.load(path)
        tree = DecisionTree(z["feature"], z["threshold"], z["left"],
                            z["right"], z["value"], int(z["n_features"]),
                            int(z["n_classes"]))
    else:
        tree = train_tree(Xtr, ytr, max_depth=spec.max_depth,
                          max_leaves=spec.max_leaves,
                          min_samples_leaf=spec.min_samples_leaf)
        np.savez(path, feature=tree.feature, threshold=tree.threshold,
                 left=tree.left, right=tree.right, value=tree.value,
                 n_features=tree.n_features, n_classes=tree.n_classes)
    return tree, (Xtr, ytr, Xte, yte)


def compiled(name: str, s: int):
    tree, data = fitted_tree(name)
    return compile_tree(tree, s), data


def emit(rows: list[dict], name: str) -> None:
    """Print a benchmark table as CSV (name,key=value CSV convention)."""
    if not rows:
        return
    keys = list(rows[0].keys())
    print(f"### {name}")
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r[k]) for k in keys))
    print()
